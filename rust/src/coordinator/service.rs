//! The sort service: EvoSort as a long-running coordinator.
//!
//! Clients submit typed [`SortRequest`]s (`i64`/`i32`/`u64`/`f64` — any
//! [`SortKey`](crate::sort::SortKey) dtype); a bounded
//! [`ThreadPool`](crate::exec::pool::ThreadPool) executes them (backpressure
//! when the queue fills), each job resolving its parameters from — in
//! priority order — the explicit override, the dtype-tagged
//! fingerprint-keyed tuning cache, or the symbolic model, then running
//! Adaptive Partition Sort and validating the output.
//!
//! Two submission paths, both non-blocking on the result side:
//!
//! * [`SortService::submit_request`] — one job, one [`Ticket`]: poll with
//!   `try_result`, park with `wait`/`wait_timeout` (condvar, zero CPU), or
//!   `cancel`. A worker lost to a panic or shutdown resolves the ticket to
//!   [`JobError::WorkerLost`](crate::coordinator::JobError::WorkerLost)
//!   instead of hanging.
//! * [`SortService::submit_batch_requests`] — many jobs in one call: the
//!   batch is sharded across the pool via a shared work queue (dynamic
//!   balancing), each worker reuses one [`SortScratch`] across all the jobs
//!   it executes, and the returned [`BatchTicket`] either barriers
//!   ([`BatchTicket::wait`] → [`BatchReport`] with p50/p99, jobs/sec and
//!   per-dtype stats) or streams ([`BatchTicket::stream`] →
//!   [`ResultStream`], yielding completed jobs in submission order as
//!   workers finish them — no whole-batch barrier).
//!
//! With `shards > 1` the same `Ticket`/`BatchTicket` surface is served by
//! the cross-process [`shard`](crate::coordinator::shard) layer instead of
//! the in-process pool; the channel/slot contracts here are the seam it
//! plugs into.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::autotune::policy::AutotunePolicy;
use crate::autotune::tuner::{Observation, OnlineTuner};
use crate::autotune::{fingerprint, Fingerprint};
use crate::coordinator::metrics::{self, names, Metrics};
use crate::coordinator::request::SortRequest;
use crate::coordinator::ticket::{CompletionGuard, JobError, JobResult, JobSlot, SortOutput, Ticket};
use crate::coordinator::tuning_cache::TuningCache;
use crate::data::validate::Verdict;
use crate::exec::{ExecMode, Executor};
use crate::extsort::{ExtError, ExtKey, ExtParams, ExtReport, ExternalConfig, ExternalSorter};
use crate::obs::{EventKind, FailReason, Tracer};
use crate::params::SortParams;
use crate::sort::key::{self, Dtype, SortKey, SortPayload, SortScratch};
use crate::sort::AdaptiveSorter;
use crate::symbolic::SymbolicModel;
use crate::util::timer;

/// Per-dtype slice of a batch (only dtypes that appeared are listed).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DtypeStats {
    pub dtype: Dtype,
    pub jobs: usize,
    pub elements: u64,
    pub mean_secs: f64,
}

/// Aggregate statistics for one completed batch.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchStats {
    pub jobs: usize,
    pub invalid: usize,
    /// Jobs that resolved to an error (cancelled / worker lost) instead of
    /// an output.
    pub failed: usize,
    /// Total elements sorted across the batch.
    pub elements: u64,
    /// Batch throughput: jobs / wall-clock seconds.
    pub jobs_per_sec: f64,
    /// Median per-job sort latency (nearest rank).
    pub p50_secs: f64,
    /// 99th-percentile per-job sort latency (nearest rank).
    pub p99_secs: f64,
    pub mean_secs: f64,
    /// Jobs in this batch whose parameters came from the tuning cache.
    pub cache_hits: u64,
    /// Jobs that fell through to the symbolic model (overrides count as
    /// neither hit nor miss).
    pub cache_misses: u64,
    /// Breakdown by key dtype, in [`Dtype::all`] order.
    pub per_dtype: Vec<DtypeStats>,
}

impl BatchStats {
    fn compute(
        outcomes: &[JobResult],
        wall_secs: f64,
        cache_hits: u64,
        cache_misses: u64,
    ) -> BatchStats {
        let jobs = outcomes.len();
        let failed = outcomes.iter().filter(|r| r.is_err()).count();
        let ok = || outcomes.iter().filter_map(|r| r.as_ref().ok());
        let invalid = ok().filter(|o| !o.valid).count();
        let elements = ok().map(|o| o.len() as u64).sum();
        let mut lats: Vec<f64> = ok().map(|o| o.secs).collect();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let (p50_secs, p99_secs, mean_secs) = if lats.is_empty() {
            (0.0, 0.0, 0.0)
        } else {
            (
                metrics::percentile_of_sorted(&lats, 50.0),
                metrics::percentile_of_sorted(&lats, 99.0),
                lats.iter().sum::<f64>() / lats.len() as f64,
            )
        };
        let jobs_per_sec = if wall_secs > 0.0 { jobs as f64 / wall_secs } else { 0.0 };
        let mut per_dtype = Vec::new();
        for &d in Dtype::all() {
            let (mut n, mut elems, mut secs_sum) = (0usize, 0u64, 0.0f64);
            for o in ok().filter(|o| o.dtype() == d) {
                n += 1;
                elems += o.len() as u64;
                secs_sum += o.secs;
            }
            if n > 0 {
                per_dtype.push(DtypeStats {
                    dtype: d,
                    jobs: n,
                    elements: elems,
                    mean_secs: secs_sum / n as f64,
                });
            }
        }
        BatchStats {
            jobs,
            invalid,
            failed,
            elements,
            jobs_per_sec,
            p50_secs,
            p99_secs,
            mean_secs,
            cache_hits,
            cache_misses,
            per_dtype,
        }
    }
}

/// The result of one batch: per-job results in submission order plus
/// throughput, latency-percentile and per-dtype statistics.
#[must_use = "a BatchReport carries the sorted payloads and the batch statistics"]
#[derive(Debug)]
pub struct BatchReport {
    pub outcomes: Vec<JobResult>,
    pub wall_secs: f64,
    pub stats: BatchStats,
}

impl BatchReport {
    /// Successful outputs only, in submission order.
    pub fn outputs(&self) -> impl Iterator<Item = &SortOutput> {
        self.outcomes.iter().filter_map(|r| r.as_ref().ok())
    }

    /// The `idx`-th job's output; panics if that job failed.
    pub fn output(&self, idx: usize) -> &SortOutput {
        self.outcomes[idx].as_ref().expect("job failed")
    }
}

/// Publishes `batch.completed` exactly once per batch — on wait, on full
/// stream drain, or (via `Drop`) when the handle/stream is abandoned — so
/// the `batch.submitted`/`batch.completed` counter pair always converges
/// even for fire-and-forget batches. The jobs themselves run to completion
/// on the pool regardless.
struct BatchCompletion {
    metrics: Arc<Metrics>,
    published: bool,
}

impl BatchCompletion {
    fn publish(&mut self) {
        if !self.published {
            self.published = true;
            self.metrics.incr(names::BATCH_COMPLETED);
        }
    }
}

impl Drop for BatchCompletion {
    fn drop(&mut self) {
        self.publish();
    }
}

/// Handle to an in-flight batch: barrier with [`wait`](BatchTicket::wait) or
/// consume incrementally with [`stream`](BatchTicket::stream). Dropping the
/// handle is fire-and-forget: the jobs still run to completion and the
/// batch still counts as completed in the metrics.
#[must_use = "wait() or stream() the BatchTicket to receive the batch results"]
pub struct BatchTicket {
    total: usize,
    started: Instant,
    rx: mpsc::Receiver<(usize, JobResult)>,
    completion: BatchCompletion,
    // Shards resolve params concurrently; each job's increment
    // happens-before its result lands on `rx`, so `wait` reads totals.
    cache_hits: Arc<AtomicU64>,
    cache_misses: Arc<AtomicU64>,
}

impl BatchTicket {
    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Block until every job in the batch resolves; results are returned in
    /// submission order and the batch gauges are published to the metrics
    /// registry (`batch.last.*`). Jobs lost to a dead worker resolve to
    /// `Err(WorkerLost)` instead of hanging the wait.
    pub fn wait(mut self) -> BatchReport {
        let mut slots: Vec<Option<JobResult>> = (0..self.total).map(|_| None).collect();
        let mut received = 0usize;
        while received < self.total {
            match self.rx.recv() {
                Ok((idx, result)) => {
                    if slots[idx].is_none() {
                        received += 1;
                    }
                    slots[idx] = Some(result);
                }
                // Every sender is gone: the unfilled slots can never arrive.
                Err(_) => break,
            }
        }
        let wall_secs = self.started.elapsed().as_secs_f64();
        let outcomes: Vec<JobResult> =
            slots.into_iter().map(|s| s.unwrap_or(Err(JobError::WorkerLost))).collect();
        let stats = BatchStats::compute(
            &outcomes,
            wall_secs,
            self.cache_hits.load(Ordering::Relaxed),
            self.cache_misses.load(Ordering::Relaxed),
        );
        self.completion.publish();
        let metrics = &self.completion.metrics;
        metrics.set_gauge(names::BATCH_LAST_JOBS_PER_SEC, stats.jobs_per_sec);
        metrics.set_gauge(names::BATCH_LAST_P50_SECS, stats.p50_secs);
        metrics.set_gauge(names::BATCH_LAST_P99_SECS, stats.p99_secs);
        BatchReport { outcomes, wall_secs, stats }
    }

    /// Cross-process constructor: the shard router feeds the same
    /// `(index, result)` channel contract the in-process pool uses, so
    /// `wait`/`stream` semantics are identical whichever side produced the
    /// results.
    pub(crate) fn from_parts(
        total: usize,
        started: Instant,
        rx: mpsc::Receiver<(usize, JobResult)>,
        metrics: Arc<Metrics>,
        cache_hits: Arc<AtomicU64>,
        cache_misses: Arc<AtomicU64>,
    ) -> BatchTicket {
        BatchTicket {
            total,
            started,
            rx,
            completion: BatchCompletion { metrics, published: false },
            cache_hits,
            cache_misses,
        }
    }

    /// Consume the batch incrementally: an iterator that yields each job's
    /// result **in submission order, as workers finish them** — result `k`
    /// is delivered as soon as jobs `0..=k` are done, while later jobs are
    /// still sorting. No whole-batch barrier.
    pub fn stream(self) -> ResultStream {
        ResultStream {
            rx: self.rx,
            buffered: HashMap::new(),
            next_idx: 0,
            total: self.total,
            completion: self.completion,
        }
    }
}

/// Incremental batch consumption (see [`BatchTicket::stream`]). Blocking
/// waits park on the underlying channel — no polling. Dropping the stream
/// early is safe: remaining jobs still run to completion (their results are
/// discarded).
#[must_use = "iterate the ResultStream to receive batch results as they complete"]
pub struct ResultStream {
    rx: mpsc::Receiver<(usize, JobResult)>,
    /// Out-of-order arrivals parked until their turn.
    buffered: HashMap<usize, JobResult>,
    next_idx: usize,
    total: usize,
    /// Publishes `batch.completed` on full drain — or on drop for abandoned
    /// streams, keeping the counter in lockstep with `batch.submitted`.
    completion: BatchCompletion,
}

impl ResultStream {
    /// Results not yet yielded.
    pub fn remaining(&self) -> usize {
        self.total - self.next_idx
    }
}

impl Iterator for ResultStream {
    type Item = JobResult;

    fn next(&mut self) -> Option<JobResult> {
        if self.next_idx >= self.total {
            return None;
        }
        loop {
            if let Some(result) = self.buffered.remove(&self.next_idx) {
                self.advance();
                return Some(result);
            }
            match self.rx.recv() {
                Ok((idx, result)) => {
                    self.buffered.insert(idx, result);
                }
                Err(_) => {
                    // Every sender is gone and the next-in-order result
                    // never arrived: the job is lost, not late.
                    self.advance();
                    return Some(Err(JobError::WorkerLost));
                }
            }
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining(), Some(self.remaining()))
    }
}

impl ExactSizeIterator for ResultStream {}

impl ResultStream {
    fn advance(&mut self) {
        self.next_idx += 1;
        if self.next_idx == self.total {
            self.completion.publish();
        }
    }
}

/// Per-dtype completion counter name (shared with the shard router, which
/// mirrors the in-process accounting for cross-process jobs).
pub(crate) fn dtype_counter(d: Dtype) -> &'static str {
    match d {
        Dtype::I64 => names::JOBS_DTYPE_I64,
        Dtype::I32 => names::JOBS_DTYPE_I32,
        Dtype::U64 => names::JOBS_DTYPE_U64,
        Dtype::F64 => names::JOBS_DTYPE_F64,
    }
}

/// Per-pool-worker scratch arena, reused across every job (and every batch)
/// a worker thread ever executes: pool workers are persistent, so
/// steady-state traffic re-sorts into warm buffers with zero allocation in
/// the sort path. [`with_worker_scratch`] is the only access path.
thread_local! {
    static WORKER_SCRATCH: std::cell::RefCell<SortScratch> =
        std::cell::RefCell::new(SortScratch::new());
}

/// Run `f` with the calling worker thread's persistent scratch arena.
fn with_worker_scratch<R>(f: impl FnOnce(&mut SortScratch) -> R) -> R {
    WORKER_SCRATCH.with(|cell| f(&mut cell.borrow_mut()))
}

/// The [`FailReason`] a trace records for a job that resolved to `err`.
pub(crate) fn fail_reason(err: &JobError) -> FailReason {
    match err {
        JobError::Cancelled => FailReason::Cancelled,
        JobError::WorkerLost => FailReason::WorkerLost,
        JobError::Overloaded => FailReason::Overloaded,
    }
}

/// Run one resolved job to completion for a concrete key dtype: optional
/// multiset fingerprint, timed sort with worker-owned scratch, total-order
/// validation, metrics accounting. With an enabled tracer the scratch's
/// phase timer is armed for the sort and drained into `kernel.<k>.<phase>`
/// samples plus per-trace `KernelPhase` events; disabled tracing leaves the
/// timer brackets as dead branches on the hot path.
fn run_typed<K: SortKey>(
    sorter: &AdaptiveSorter,
    metrics: &Metrics,
    tracer: &Tracer,
    trace_id: u64,
    id: u64,
    mut data: Vec<K>,
    validate: bool,
    params: SortParams,
    scratch: &mut SortScratch,
) -> SortOutput {
    let threads = sorter.threads();
    // Fingerprint/validation sweeps run on the service-owned executor too —
    // a deployment never lazily constructs (or leaks work onto) the global
    // pool.
    let exec = sorter.executor();
    let fp = validate.then(|| key::fingerprint_keys_on(exec, &data, threads));
    let grows_before = scratch.grows();
    let traced = tracer.is_enabled();
    scratch.timer_mut().set_enabled(traced);
    let (_, secs) = timer::time(|| K::sort_with(sorter, &mut data, &params, scratch));
    if traced {
        for (phase, dur) in scratch.timer_mut().drain() {
            tracer.emit(trace_id, EventKind::KernelPhase { phase, dur_secs: dur });
            metrics.observe_sample(phase.metric_name(), dur);
        }
    }
    let grew = scratch.grows() - grows_before;
    let valid = match fp {
        Some(fp) => key::validate_keys_on(exec, fp, &data, threads) == Verdict::Valid,
        None => true,
    };
    metrics.incr(names::JOBS_COMPLETED);
    metrics.incr(dtype_counter(K::DTYPE));
    metrics.observe(names::SORT_LATENCY, secs);
    metrics.add(names::ELEMENTS_SORTED, data.len() as u64);
    if grew > 0 {
        // Arena growth events — flat once the service is warm; the
        // steady-state test gates on this counter.
        metrics.add(names::SCRATCH_GROWS, grew);
    }
    if !valid {
        metrics.incr(names::JOBS_INVALID);
    }
    SortOutput { id, payload: K::into_payload(data), params, secs, valid }
}

/// Out-of-core variant of [`run_typed`]: the same adaptive kernels form
/// sorted runs, the runs spill through a guarded per-job directory, and the
/// loser-tree merge streams chunks that are reassembled into one output
/// payload (the single-`Ticket` contract; [`SortService::submit_external_streaming`]
/// is the chunk-at-a-time surface). Run-formation/spill/merge timings drain
/// as `kernel.ext.*` phases next to the per-run kernel phases. A spill-path
/// failure (I/O, corrupt run) resolves to `valid = false` — the guard has
/// already removed the spill directory — rather than poisoning the worker.
fn run_external_typed<K: ExtKey>(
    sorter: &AdaptiveSorter,
    metrics: &Metrics,
    tracer: &Tracer,
    trace_id: u64,
    id: u64,
    data: Vec<K>,
    validate: bool,
    params: SortParams,
    ext: ExtParams,
    config: &ExternalConfig,
    scratch: &mut SortScratch,
) -> SortOutput {
    let threads = sorter.threads();
    let exec = sorter.executor();
    let fp = validate.then(|| key::fingerprint_keys_on(exec, &data, threads));
    let n = data.len();
    let grows_before = scratch.grows();
    let traced = tracer.is_enabled();
    scratch.timer_mut().set_enabled(traced);
    let external = ExternalSorter::new(sorter, config);
    let mut out: Vec<K> = Vec::with_capacity(n);
    let (result, secs) = timer::time(|| {
        external.sort_streaming(
            data,
            &params,
            ext,
            scratch,
            &mut |chunk| {
                out.extend_from_slice(&chunk);
                Ok(())
            },
            &mut || false,
        )
    });
    if traced {
        for (phase, dur) in scratch.timer_mut().drain() {
            tracer.emit(trace_id, EventKind::KernelPhase { phase, dur_secs: dur });
            metrics.observe_sample(phase.metric_name(), dur);
        }
    }
    let grew = scratch.grows() - grows_before;
    let ok = match result {
        Ok(report) => {
            metrics.incr(names::EXTSORT_JOBS);
            metrics.add(names::EXTSORT_RUNS_SPILLED, report.runs_spilled);
            metrics.add(names::EXTSORT_MERGE_PASSES, report.merge_passes);
            metrics.add(names::EXTSORT_CHUNKS_STREAMED, report.chunks_streamed);
            metrics.set_gauge(names::EXTSORT_LAST_PEAK_BYTES, report.peak_working_bytes as f64);
            true
        }
        Err(e) => {
            metrics.incr(names::EXTSORT_ERRORS);
            crate::log_warn!("external sort failed (job {id}): {e}");
            false
        }
    };
    let valid = ok
        && out.len() == n
        && match fp {
            Some(fp) => key::validate_keys_on(exec, fp, &out, threads) == Verdict::Valid,
            None => true,
        };
    metrics.incr(names::JOBS_COMPLETED);
    metrics.incr(dtype_counter(K::DTYPE));
    metrics.observe(names::SORT_LATENCY, secs);
    metrics.add(names::ELEMENTS_SORTED, out.len() as u64);
    if grew > 0 {
        metrics.add(names::SCRATCH_GROWS, grew);
    }
    if !valid {
        metrics.incr(names::JOBS_INVALID);
    }
    SortOutput { id, payload: K::into_payload(out), params, secs, valid }
}

/// Drive one out-of-core job for [`SortService::submit_external_streaming`],
/// sending each merged chunk through the batch channel as its own
/// [`SortOutput`] the moment the loser tree produces it. Returns the sort
/// result plus total wall seconds. A dropped receiver flips the cancel
/// probe, so an abandoned stream tears the merge down (and the spill
/// directory with it) instead of sorting into the void.
fn stream_external_typed<K: ExtKey>(
    sorter: &AdaptiveSorter,
    metrics: &Metrics,
    tracer: &Tracer,
    trace_id: u64,
    id: u64,
    data: Vec<K>,
    params: SortParams,
    ext: ExtParams,
    config: &ExternalConfig,
    scratch: &mut SortScratch,
    tx: &mpsc::Sender<(usize, JobResult)>,
) -> (Result<ExtReport, ExtError>, f64) {
    let traced = tracer.is_enabled();
    scratch.timer_mut().set_enabled(traced);
    let external = ExternalSorter::new(sorter, config);
    let started = Instant::now();
    let gone = std::cell::Cell::new(false);
    let mut idx = 0usize;
    let result = external.sort_streaming(
        data,
        &params,
        ext,
        scratch,
        &mut |chunk| {
            let out = SortOutput {
                id,
                payload: K::into_payload(chunk),
                params,
                secs: started.elapsed().as_secs_f64(),
                valid: true,
            };
            if tx.send((idx, Ok(out))).is_err() {
                gone.set(true);
            }
            idx += 1;
            Ok(())
        },
        &mut || gone.get(),
    );
    let secs = started.elapsed().as_secs_f64();
    if traced {
        for (phase, dur) in scratch.timer_mut().drain() {
            tracer.emit(trace_id, EventKind::KernelPhase { phase, dur_secs: dur });
            metrics.observe_sample(phase.metric_name(), dur);
        }
    }
    (result, secs)
}

/// Dtype dispatch over the erased payload — shared by the single-job and
/// batched submission paths. `ext = Some(genes)` routes the job through the
/// out-of-core sorter under `config` instead of the in-RAM kernels.
fn execute_request(
    sorter: &AdaptiveSorter,
    metrics: &Metrics,
    tracer: &Tracer,
    id: u64,
    req: SortRequest,
    params: SortParams,
    escalation: Option<(&ExternalConfig, ExtParams)>,
    scratch: &mut SortScratch,
) -> SortOutput {
    let tid = req.trace_id.unwrap_or(id);
    let SortRequest { payload, validate, .. } = req;
    if let Some((config, ext)) = escalation {
        return match payload {
            SortPayload::I64(v) => run_external_typed(
                sorter, metrics, tracer, tid, id, v, validate, params, ext, config, scratch,
            ),
            SortPayload::I32(v) => run_external_typed(
                sorter, metrics, tracer, tid, id, v, validate, params, ext, config, scratch,
            ),
            SortPayload::U64(v) => run_external_typed(
                sorter, metrics, tracer, tid, id, v, validate, params, ext, config, scratch,
            ),
            SortPayload::F64(v) => run_external_typed(
                sorter, metrics, tracer, tid, id, v, validate, params, ext, config, scratch,
            ),
        };
    }
    match payload {
        SortPayload::I64(v) => {
            run_typed(sorter, metrics, tracer, tid, id, v, validate, params, scratch)
        }
        SortPayload::I32(v) => {
            run_typed(sorter, metrics, tracer, tid, id, v, validate, params, scratch)
        }
        SortPayload::U64(v) => {
            run_typed(sorter, metrics, tracer, tid, id, v, validate, params, scratch)
        }
        SortPayload::F64(v) => {
            run_typed(sorter, metrics, tracer, tid, id, v, validate, params, scratch)
        }
    }
}

/// Dtype-tagged fingerprint label of a payload (the tuning-cache key).
pub(crate) fn payload_label(payload: &SortPayload) -> String {
    match payload {
        SortPayload::I64(v) => Fingerprint::of_keys(v.as_slice()).label(),
        SortPayload::I32(v) => Fingerprint::of_keys(v.as_slice()).label(),
        SortPayload::U64(v) => Fingerprint::of_keys(v.as_slice()).label(),
        SortPayload::F64(v) => Fingerprint::of_keys(v.as_slice()).label(),
    }
}

/// Strided i64-projected sample of a payload (retained GA-fitness input).
fn payload_sample(payload: &SortPayload, cap: usize) -> Vec<i64> {
    match payload {
        SortPayload::I64(v) => fingerprint::sample_keys(v.as_slice(), cap),
        SortPayload::I32(v) => fingerprint::sample_keys(v.as_slice(), cap),
        SortPayload::U64(v) => fingerprint::sample_keys(v.as_slice(), cap),
        SortPayload::F64(v) => fingerprint::sample_keys(v.as_slice(), cap),
    }
}

/// Service configuration.
#[derive(Clone)]
pub struct ServiceConfig {
    /// Concurrent sort jobs (each job internally uses `sort_threads`).
    pub workers: usize,
    /// Threads each sort uses.
    pub sort_threads: usize,
    /// Pending-job queue bound (backpressure).
    pub queue_capacity: usize,
    /// When set, the service owns an [`OnlineTuner`]: jobs feed fingerprint
    /// + latency observations to a background thread that refines cached
    /// parameters with incremental GA generations.
    pub autotune: Option<AutotunePolicy>,
    /// Execution backend for the data-parallel sort kernels. `Parked`
    /// (default) builds one persistent parked [`Executor`] per service,
    /// sized `workers x sort_threads`, shared by every pool worker's jobs;
    /// `SpawnPerCall` restores the historical scoped-spawn behaviour (A/B
    /// benchmarking, debugging).
    pub exec: ExecMode,
    /// Out-of-core escalation: jobs whose payload exceeds the configured
    /// memory budget run through the [`extsort`](crate::extsort) subsystem
    /// (spilled runs + streaming loser-tree merge) instead of wholly in RAM.
    /// `None` (default) never escalates.
    pub external: Option<ExternalConfig>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        let hw = crate::util::default_threads();
        ServiceConfig {
            workers: 2,
            sort_threads: hw.div_ceil(2),
            queue_capacity: 64,
            autotune: None,
            exec: ExecMode::Parked,
            external: None,
        }
    }
}

// The builder below is the only sanctioned way to assemble a config outside
// this module: `cargo xtask lint` rejects `ServiceConfig` struct literals
// elsewhere, so adding a field means touching exactly this file (plus the
// places that opt into the new field) instead of every construction site.
impl ServiceConfig {
    /// The default configuration; chain `with_*` setters to customise.
    pub fn new() -> Self {
        Self::default()
    }

    /// Explicitly sized config — the common construction shape
    /// (`workers` x `sort_threads`, `queue_capacity` pending-job bound).
    pub fn sized(workers: usize, sort_threads: usize, queue_capacity: usize) -> Self {
        Self::new()
            .with_workers(workers)
            .with_sort_threads(sort_threads)
            .with_queue_capacity(queue_capacity)
    }

    /// Set the concurrent-job worker count.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Set the per-sort thread budget.
    pub fn with_sort_threads(mut self, sort_threads: usize) -> Self {
        self.sort_threads = sort_threads;
        self
    }

    /// Set the pending-job queue bound.
    pub fn with_queue_capacity(mut self, queue_capacity: usize) -> Self {
        self.queue_capacity = queue_capacity;
        self
    }

    /// Attach (or detach, with `None`) a background autotune policy.
    pub fn with_autotune(mut self, autotune: impl Into<Option<AutotunePolicy>>) -> Self {
        self.autotune = autotune.into();
        self
    }

    /// Select the kernel execution backend.
    pub fn with_exec(mut self, exec: ExecMode) -> Self {
        self.exec = exec;
        self
    }

    /// Attach (or detach, with `None`) out-of-core escalation.
    pub fn with_external(mut self, external: impl Into<Option<ExternalConfig>>) -> Self {
        self.external = external.into();
        self
    }
}

/// A job's resolved parameters plus the observation the tuner wants back.
struct Resolution {
    params: SortParams,
    /// True when the parameters came from the tuning cache (false for
    /// overrides and symbolic fallbacks).
    cache_hit: bool,
    /// `(fingerprint label, retained pre-sort sample)` — `None` for
    /// explicit-override jobs or when autotuning is off. Escalated jobs
    /// carry the beyond-memory (`:xm`) label so the tuner refines the
    /// spill genes of the out-of-core class, not the in-RAM one.
    observe: Option<(String, Vec<i64>)>,
    /// `Some(spill genes)` when the job escalates to the external sorter.
    ext: Option<ExtParams>,
}

/// The coordinator service.
pub struct SortService {
    // Field order is drop order: the pool joins its workers (which hold
    // transient `Arc<OnlineTuner>` clones) before the tuner itself is
    // dropped and joined.
    pool: crate::exec::pool::ThreadPool,
    sorter: Arc<AdaptiveSorter>,
    cache: Arc<TuningCache>,
    model: SymbolicModel,
    metrics: Arc<Metrics>,
    tuner: Option<Arc<OnlineTuner>>,
    tracer: Tracer,
    external: Option<ExternalConfig>,
    next_id: AtomicU64,
}

/// Resolve parameters for one request against shared service state:
/// override → dtype-tagged fingerprint-keyed cache → symbolic model. The
/// declared `req.dist` label is NOT consulted — the cache key comes from the
/// data itself, so mislabeled jobs cannot poison the cache, and the dtype
/// tag keeps (say) f64 classes from ever colliding with i64 ones.
///
/// A free function over the shared (`Arc`ed) state so the batched path can
/// run it *inside* worker shards: the fingerprint probe then parallelises
/// with the sorting instead of serialising on the submitting thread.
fn resolve_request(
    cache: &TuningCache,
    model: &SymbolicModel,
    metrics: &Metrics,
    tuner: Option<&OnlineTuner>,
    external: Option<&ExternalConfig>,
    req: &SortRequest,
) -> Resolution {
    // The escalation decision is size-only, taken against the config-level
    // genes (the operator override or the defaults) — it must not depend on
    // which tuned class the data happens to land in, or a cache update could
    // flip a job between the in-RAM and out-of-core paths mid-stream.
    let escalate = external.is_some_and(|x| {
        let probe = x.params.unwrap_or_default();
        x.escalates(req.len() * req.dtype().width(), req.len(), &probe)
    });
    let ext_genes = |label: Option<&str>| {
        external
            .and_then(|x| x.params)
            .or_else(|| label.and_then(|l| cache.get_ext(req.len(), l)))
            .unwrap_or_default()
    };
    if let Some(p) = req.params {
        metrics.incr(names::PARAMS_OVERRIDE);
        let ext = escalate.then(|| ext_genes(None));
        return Resolution { params: p, cache_hit: false, observe: None, ext };
    }
    let base = payload_label(&req.payload);
    let label =
        if escalate { fingerprint::beyond_memory_label(&base) } else { base.clone() };
    let (params, cache_hit) = if let Some(p) = cache.get(req.len(), &label) {
        metrics.incr(names::PARAMS_CACHE_HIT);
        (p, true)
    } else {
        metrics.incr(names::PARAMS_CACHE_MISS);
        // An escalated class that has never been tuned borrows the in-RAM
        // class's run-formation parameters before falling back to the model.
        let fallback = if escalate { cache.get(req.len(), &base) } else { None };
        match fallback {
            Some(p) => (p, false),
            None => {
                metrics.incr(names::PARAMS_SYMBOLIC);
                (model.params_for(req.len()), false)
            }
        }
    };
    let ext = escalate.then(|| ext_genes(Some(&label)));
    // Retain a strided pre-sort sample for the tuner's GA fitness (the
    // post-sort data is sorted, which would bias tuning toward the
    // sorted-input special case). The copy is taken on only every k-th
    // job — the tuner keeps one sample per class, so paying the memcpy
    // for every job would be pure waste. An empty sample means "latency
    // observation only"; the tuner ignores it for fitness.
    let observe = tuner.map(|t| {
        let sample = if t.wants_sample(&label) {
            payload_sample(&req.payload, t.policy().retained_sample_cap)
        } else {
            Vec::new()
        };
        (label, sample)
    });
    Resolution { params, cache_hit, observe, ext }
}

impl SortService {
    pub fn new(config: ServiceConfig) -> Self {
        Self::with_sorter(config, AdaptiveSorter::new(1))
    }

    /// [`new`](Self::new) with end-to-end tracing attached: every job emits
    /// `Submitted → Queued → Dispatched → KernelPhase* → Completed/Failed`
    /// span events into the tracer's ring (non-blocking; ring-full drops are
    /// counted, never stall a sort), and the tuner's publish/reject
    /// decisions are traced too.
    pub fn new_traced(config: ServiceConfig, tracer: Tracer) -> Self {
        Self::with_sorter_traced(config, AdaptiveSorter::new(1), tracer)
    }

    /// Build with a prepared sorter (e.g. XLA backend attached). The sorter's
    /// thread budget is replaced by `config.sort_threads`, and its executor
    /// by a service-owned pool sized to the deployment
    /// (`workers x sort_threads`) in the configured [`ExecMode`].
    pub fn with_sorter(config: ServiceConfig, sorter: AdaptiveSorter) -> Self {
        Self::with_sorter_traced(config, sorter, Tracer::disabled())
    }

    /// [`with_sorter`](Self::with_sorter) plus a [`Tracer`] (see
    /// [`new_traced`](Self::new_traced)).
    pub fn with_sorter_traced(config: ServiceConfig, sorter: AdaptiveSorter, tracer: Tracer) -> Self {
        let width = (config.workers.max(1) * config.sort_threads.max(1)).max(1);
        let executor = Arc::new(match config.exec {
            ExecMode::Parked => Executor::new(width),
            ExecMode::SpawnPerCall => Executor::spawn_per_call(width),
        });
        let sorter = sorter.rebudget(config.sort_threads).with_executor(executor);
        let cache = Arc::new(TuningCache::new());
        let metrics = Arc::new(Metrics::new());
        let model = SymbolicModel::paper();
        let tuner = config.autotune.map(|policy| {
            Arc::new(OnlineTuner::spawn(
                policy,
                Arc::clone(&cache),
                Arc::clone(&metrics),
                model,
                config.sort_threads,
                tracer.clone(),
            ))
        });
        SortService {
            pool: crate::exec::pool::ThreadPool::with_capacity(
                config.workers,
                config.queue_capacity,
            ),
            sorter: Arc::new(sorter),
            cache,
            model,
            metrics,
            tuner,
            tracer,
            external: config.external,
            next_id: AtomicU64::new(1),
        }
    }

    /// Replace the symbolic model (e.g. one fitted on this machine).
    pub fn set_model(&mut self, model: SymbolicModel) {
        self.model = model;
    }

    pub fn cache(&self) -> &Arc<TuningCache> {
        &self.cache
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// The service's tracer (disabled unless built via
    /// [`new_traced`](Self::new_traced) /
    /// [`with_sorter_traced`](Self::with_sorter_traced)).
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Whether a background tuner is attached.
    pub fn autotuning(&self) -> bool {
        self.tuner.is_some()
    }

    /// The fingerprint label i64 `data` would resolve through — the
    /// tuning-cache key. Use this (not the declared distribution name) to
    /// pre-warm the cache.
    pub fn fingerprint_label(data: &[i64]) -> String {
        Fingerprint::of(data).label()
    }

    /// Dtype-generic [`fingerprint_label`](Self::fingerprint_label): labels
    /// for non-i64 dtypes carry the dtype tag, e.g. `b10:mix:uniq:w8:pm:f64`.
    pub fn fingerprint_label_for<K: SortKey>(data: &[K]) -> String {
        Fingerprint::of_keys(data).label()
    }

    /// Submit one typed request; blocks only while the job queue is full
    /// (backpressure), never for the result — that arrives through the
    /// returned [`Ticket`].
    pub fn submit_request(&self, req: SortRequest) -> Ticket {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let tid = req.trace_id.unwrap_or(id);
        self.tracer.emit(tid, EventKind::Submitted);
        let slot = JobSlot::pending();
        // The terminal observer fires on whichever resolution wins the slot
        // — explicit completion, cancel, or the guard's WorkerLost drop —
        // so every submitted job emits exactly one terminal trace event.
        let guard = if self.tracer.is_enabled() {
            let tracer = self.tracer.clone();
            CompletionGuard::new(Arc::clone(&slot)).with_observer(Box::new(move |result| {
                match result {
                    Ok(out) => tracer.emit(tid, EventKind::Completed { secs: out.secs }),
                    Err(e) => tracer.emit(tid, EventKind::Failed { reason: fail_reason(e) }),
                }
            }))
        } else {
            CompletionGuard::new(Arc::clone(&slot))
        };
        let sorter = Arc::clone(&self.sorter);
        let metrics = Arc::clone(&self.metrics);
        let tracer = self.tracer.clone();
        let Resolution { params, observe, ext, .. } = resolve_request(
            &self.cache,
            &self.model,
            &self.metrics,
            self.tuner.as_deref(),
            self.external.as_ref(),
            &req,
        );
        let external = self.external.clone();
        let tuner = self.tuner.clone();
        self.metrics.incr(names::JOBS_SUBMITTED);
        self.tracer.emit(tid, EventKind::Queued);
        // If the pool refuses (shutdown) the closure is dropped unexecuted
        // and the guard resolves the ticket to WorkerLost — same for a
        // worker panic mid-sort. `wait` can always return.
        let _ = self.pool.submit(move || {
            // Marks the slot Running (refusing later cancels) or honours a
            // cancel that landed while the job was queued.
            if guard.start() {
                guard.complete(Err(JobError::Cancelled));
                return;
            }
            tracer.emit(tid, EventKind::Dispatched { shard: tracer.shard() });
            let escalation = external.as_ref().and_then(|c| ext.map(|x| (c, x)));
            let outcome = with_worker_scratch(|scratch| {
                execute_request(&sorter, &metrics, &tracer, id, req, params, escalation, scratch)
            });
            if let (Some(tuner), Some((label, sample))) = (&tuner, observe) {
                tuner.observe(Observation {
                    label,
                    n: outcome.len(),
                    secs: outcome.secs,
                    sample: Some(sample),
                });
            }
            guard.complete(Ok(outcome));
        });
        Ticket::new(id, slot)
    }

    /// Submit a whole batch of typed requests in one call.
    ///
    /// The submit call itself only assigns ids and enqueues: parameter
    /// resolution (fingerprint probe + cache/model lookup) runs *inside*
    /// the worker shards, so probing parallelises with sorting and the
    /// caller returns immediately. Jobs flow through a shared work queue
    /// drained by up to `pool.threads()` pool tasks, so shards balance
    /// dynamically under mixed job sizes and every shard reuses a single
    /// [`SortScratch`] across all the jobs it executes. A job that panics
    /// resolves to `Err(WorkerLost)` without taking the rest of the batch
    /// down. Per-job latencies stream into the `batch.job.latency` sample
    /// window; [`BatchTicket::wait`] publishes p50/p99/jobs-per-sec plus the
    /// batch's tuning-cache hit/miss counts and per-dtype stats.
    pub fn submit_batch_requests(&self, requests: Vec<SortRequest>) -> BatchTicket {
        let started = Instant::now();
        let total = requests.len();
        let (tx, rx) = mpsc::channel();
        // Keep the shared counters consistent with the single-job path
        // (jobs.submitted >= jobs.completed must hold across mixed traffic).
        self.metrics.add(names::JOBS_SUBMITTED, total as u64);
        self.metrics.add(names::BATCH_JOBS_SUBMITTED, total as u64);
        self.metrics.incr(names::BATCH_SUBMITTED);
        let cache_hits = Arc::new(AtomicU64::new(0));
        let cache_misses = Arc::new(AtomicU64::new(0));
        let queue: VecDeque<(usize, u64, SortRequest)> = requests
            .into_iter()
            .enumerate()
            .map(|(idx, req)| {
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                let tid = req.trace_id.unwrap_or(id);
                self.tracer.emit(tid, EventKind::Submitted);
                self.tracer.emit(tid, EventKind::Queued);
                (idx, id, req)
            })
            .collect();
        let queue = Arc::new(Mutex::new(queue));
        let shards = self.pool.threads().min(total.max(1));
        for _ in 0..shards {
            let queue = Arc::clone(&queue);
            let sorter = Arc::clone(&self.sorter);
            let cache = Arc::clone(&self.cache);
            let model = self.model;
            let metrics = Arc::clone(&self.metrics);
            let tuner = self.tuner.clone();
            let tracer = self.tracer.clone();
            let external = self.external.clone();
            let hits = Arc::clone(&cache_hits);
            let misses = Arc::clone(&cache_misses);
            let tx = tx.clone();
            let submitted = self.pool.submit(move || {
                // The worker thread's persistent scratch arena, reused
                // across every job this shard pulls (whatever its dtype)
                // and across batches — steady-state traffic allocates
                // nothing here.
                with_worker_scratch(|scratch| loop {
                    let item = queue.lock().unwrap().pop_front();
                    let Some((idx, id, req)) = item else { break };
                    let tid = req.trace_id.unwrap_or(id);
                    tracer.emit(tid, EventKind::Dispatched { shard: tracer.shard() });
                    let has_override = req.params.is_some();
                    // Per-job panic isolation: a poisonous job resolves to
                    // an error; the shard keeps draining the queue.
                    let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        let Resolution { params, cache_hit, observe, ext } = resolve_request(
                            &cache,
                            &model,
                            &metrics,
                            tuner.as_deref(),
                            external.as_ref(),
                            &req,
                        );
                        if !has_override {
                            if cache_hit {
                                hits.fetch_add(1, Ordering::Relaxed);
                            } else {
                                misses.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        let escalation = external.as_ref().and_then(|c| ext.map(|x| (c, x)));
                        let outcome = execute_request(
                            &sorter, &metrics, &tracer, id, req, params, escalation, &mut *scratch,
                        );
                        metrics.observe_sample(names::BATCH_JOB_LATENCY, outcome.secs);
                        if let (Some(tuner), Some((label, sample))) = (&tuner, observe) {
                            tuner.observe(Observation {
                                label,
                                n: outcome.len(),
                                secs: outcome.secs,
                                sample: Some(sample),
                            });
                        }
                        outcome
                    }));
                    let result: JobResult = match ran {
                        Ok(outcome) => {
                            tracer.emit(tid, EventKind::Completed { secs: outcome.secs });
                            Ok(outcome)
                        }
                        Err(_) => {
                            metrics.incr(names::JOBS_PANICKED);
                            tracer
                                .emit(tid, EventKind::Failed { reason: FailReason::WorkerLost });
                            Err(JobError::WorkerLost)
                        }
                    };
                    let _ = tx.send((idx, result));
                })
            });
            if !submitted {
                // Pool shutting down: the dropped closure sent nothing; the
                // remaining queue items resolve as WorkerLost in wait().
                break;
            }
        }
        BatchTicket {
            total,
            started,
            rx,
            completion: BatchCompletion { metrics: Arc::clone(&self.metrics), published: false },
            cache_hits,
            cache_misses,
        }
    }

    /// Out-of-core submission with **streaming** results: the job always
    /// runs through the external sorter (no budget test — callers pick this
    /// surface precisely because the payload should not stay resident), and
    /// the returned [`BatchTicket`] yields each merged chunk as its own
    /// [`SortOutput`], in key order. `stream()` hands over the first chunk
    /// while later chunks are still merging, so consumption overlaps the
    /// merge; the ticket's length is the spill plan's chunk count. Chunk
    /// outputs skip multiset validation (each chunk is sorted by
    /// construction; cross-chunk validation would re-materialise the whole
    /// payload). Dropping the stream cancels the merge and removes the
    /// spill files.
    ///
    /// Uses the service's [`ExternalConfig`] when one is configured; without
    /// one, a default config (temp-dir spill root, minimum budget) applies.
    pub fn submit_external_streaming(&self, req: SortRequest) -> BatchTicket {
        let started = Instant::now();
        let config = self.external.clone().unwrap_or_else(|| ExternalConfig::new(0));
        // Resolution must see an always-escalating config: the job resolves
        // through the beyond-memory class even when it would fit the budget.
        let mut forced = config.clone();
        forced.memory_budget = 0;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let tid = req.trace_id.unwrap_or(id);
        self.tracer.emit(tid, EventKind::Submitted);
        self.metrics.incr(names::JOBS_SUBMITTED);
        self.metrics.incr(names::BATCH_SUBMITTED);
        let cache_hits = Arc::new(AtomicU64::new(0));
        let cache_misses = Arc::new(AtomicU64::new(0));
        // Resolve on the submitting thread: the ticket's chunk-count
        // contract depends on the resolved spill genes.
        let Resolution { params, cache_hit, observe, ext } = resolve_request(
            &self.cache,
            &self.model,
            &self.metrics,
            self.tuner.as_deref(),
            Some(&forced),
            &req,
        );
        if req.params.is_none() {
            if cache_hit {
                cache_hits.fetch_add(1, Ordering::Relaxed);
            } else {
                cache_misses.fetch_add(1, Ordering::Relaxed);
            }
        }
        let ext = ext.unwrap_or_default();
        let n = req.len();
        let total =
            crate::extsort::plan(n, req.dtype().width(), config.memory_budget, ext).total_chunks;
        let dtype = req.dtype();
        let (tx, rx) = mpsc::channel();
        let sorter = Arc::clone(&self.sorter);
        let metrics = Arc::clone(&self.metrics);
        let tracer = self.tracer.clone();
        let tuner = self.tuner.clone();
        self.tracer.emit(tid, EventKind::Queued);
        // A refused submit (pool shutdown) drops tx unexecuted; the ticket
        // resolves every chunk slot as WorkerLost instead of hanging.
        let _ = self.pool.submit(move || {
            tracer.emit(tid, EventKind::Dispatched { shard: tracer.shard() });
            let SortRequest { payload, .. } = req;
            let (result, secs) = with_worker_scratch(|scratch| match payload {
                SortPayload::I64(v) => stream_external_typed(
                    &sorter, &metrics, &tracer, tid, id, v, params, ext, &config, scratch, &tx,
                ),
                SortPayload::I32(v) => stream_external_typed(
                    &sorter, &metrics, &tracer, tid, id, v, params, ext, &config, scratch, &tx,
                ),
                SortPayload::U64(v) => stream_external_typed(
                    &sorter, &metrics, &tracer, tid, id, v, params, ext, &config, scratch, &tx,
                ),
                SortPayload::F64(v) => stream_external_typed(
                    &sorter, &metrics, &tracer, tid, id, v, params, ext, &config, scratch, &tx,
                ),
            });
            match result {
                Ok(report) => {
                    metrics.incr(names::JOBS_COMPLETED);
                    metrics.incr(dtype_counter(dtype));
                    metrics.observe(names::SORT_LATENCY, secs);
                    metrics.add(names::ELEMENTS_SORTED, report.elements);
                    metrics.incr(names::EXTSORT_JOBS);
                    metrics.add(names::EXTSORT_RUNS_SPILLED, report.runs_spilled);
                    metrics.add(names::EXTSORT_MERGE_PASSES, report.merge_passes);
                    metrics.add(names::EXTSORT_CHUNKS_STREAMED, report.chunks_streamed);
                    metrics.set_gauge(
                        names::EXTSORT_LAST_PEAK_BYTES,
                        report.peak_working_bytes as f64,
                    );
                    tracer.emit(tid, EventKind::Completed { secs });
                    if let (Some(tuner), Some((label, sample))) = (&tuner, observe) {
                        tuner.observe(Observation { label, n, secs, sample: Some(sample) });
                    }
                }
                Err(ExtError::Cancelled) => {
                    metrics.incr(names::EXTSORT_CANCELLED);
                    tracer.emit(tid, EventKind::Failed { reason: FailReason::Cancelled });
                }
                Err(e) => {
                    metrics.incr(names::EXTSORT_ERRORS);
                    crate::log_warn!("external stream failed (job {id}): {e}");
                    tracer.emit(tid, EventKind::Failed { reason: FailReason::WorkerLost });
                }
            }
        });
        BatchTicket {
            total,
            started,
            rx,
            completion: BatchCompletion { metrics: Arc::clone(&self.metrics), published: false },
            cache_hits,
            cache_misses,
        }
    }

    /// Block until every submitted job has completed. Parks on the worker
    /// pool's idle condvar — an idle drain costs zero CPU (no polling loop).
    pub fn drain(&self) {
        self.pool.wait_idle();
    }

    /// Bounded [`drain`](Self::drain): parks for at most `timeout`,
    /// returning `true` if the service went idle in time.
    pub fn drain_timeout(&self, timeout: Duration) -> bool {
        self.pool.wait_idle_timeout(timeout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_i64, Distribution};

    fn service() -> SortService {
        SortService::new(ServiceConfig::sized(2, 2, 8))
    }

    fn sorted_i64(out: &SortOutput) -> Vec<i64> {
        out.data::<i64>().expect("i64 payload").to_vec()
    }

    #[test]
    fn submit_and_wait_sorted() {
        let svc = service();
        let data = generate_i64(150_000, Distribution::Uniform, 1, 2);
        let mut expect = data.clone();
        expect.sort_unstable();
        let out = svc.submit_request(SortRequest::new(data)).wait().expect("job ok");
        assert!(out.valid);
        assert_eq!(out.dtype(), Dtype::I64);
        assert_eq!(sorted_i64(&out), expect);
        assert!(out.secs > 0.0);
        assert_eq!(svc.metrics().counter(names::JOBS_COMPLETED), 1);
        assert_eq!(svc.metrics().counter(names::JOBS_DTYPE_I64), 1);
    }

    #[test]
    fn traced_service_emits_complete_span_chains() {
        use crate::obs::{report, Tracer};
        let tracer = Tracer::enabled(1024, 0);
        let svc = SortService::new_traced(
            ServiceConfig::sized(2, 2, 8),
            tracer,
        );
        let data = generate_i64(150_000, Distribution::Uniform, 21, 2);
        let out = svc.submit_request(SortRequest::new(data)).wait().expect("job ok");
        assert!(out.valid);
        let mut events = Vec::new();
        svc.tracer().drain_into(&mut events);
        for kind in ["submitted", "queued", "dispatched", "completed"] {
            assert!(events.iter().any(|e| e.kind.name() == kind), "{kind} missing: {events:?}");
        }
        // The sort reported at least one kernel phase, and the phase also
        // landed in the metrics sample windows under kernel.<k>.<phase>.
        let phase = events
            .iter()
            .find_map(|e| match e.kind {
                EventKind::KernelPhase { phase, .. } => Some(phase),
                _ => None,
            })
            .expect("traced sort reports kernel phases");
        assert!(svc.metrics().percentile(phase.metric_name(), 50.0).is_some());
        // Exactly one terminal event, and the chain checker is satisfied.
        assert_eq!(events.iter().filter(|e| e.kind.is_terminal()).count(), 1);
        assert_eq!(report::check(&events), Vec::<String>::new());
        assert_eq!(svc.tracer().dropped(), 0);
    }

    #[test]
    fn untraced_service_skips_phase_accounting() {
        let svc = service();
        let data = generate_i64(100_000, Distribution::Uniform, 22, 2);
        let out = svc.submit_request(SortRequest::new(data)).wait().expect("job ok");
        assert!(out.valid);
        assert!(!svc.tracer().is_enabled());
        let mut events = Vec::new();
        assert_eq!(svc.tracer().drain_into(&mut events), 0);
        for p in crate::obs::Phase::all() {
            assert!(svc.metrics().percentile(p.metric_name(), 50.0).is_none());
        }
    }

    #[test]
    fn many_concurrent_jobs() {
        let svc = service();
        let tickets: Vec<Ticket> = (0..10u64)
            .map(|seed| {
                let data = generate_i64(30_000, Distribution::Uniform, seed, 2);
                svc.submit_request(SortRequest::new(data))
            })
            .collect();
        let mut ids = std::collections::HashSet::new();
        for t in tickets {
            let out = t.wait().expect("job ok");
            assert!(out.valid);
            assert!(sorted_i64(&out).windows(2).all(|w| w[0] <= w[1]));
            ids.insert(out.id);
        }
        assert_eq!(ids.len(), 10, "unique job ids");
        assert_eq!(svc.metrics().counter(names::JOBS_COMPLETED), 10);
        assert_eq!(svc.metrics().counter(names::JOBS_INVALID), 0);
    }

    #[test]
    fn params_resolution_order() {
        let svc = service();
        // 1. symbolic (cold cache).
        let out = svc
            .submit_request(SortRequest::new(generate_i64(200_000, Distribution::Uniform, 3, 2)))
            .wait()
            .unwrap();
        assert!(out.valid);
        assert_eq!(svc.metrics().counter(names::PARAMS_SYMBOLIC), 1);
        assert_eq!(svc.metrics().counter(names::PARAMS_CACHE_MISS), 1);
        // 2. cache hit after put under the data's fingerprint label.
        let data = generate_i64(200_000, Distribution::Uniform, 4, 2);
        let label = SortService::fingerprint_label(&data);
        svc.cache().put(data.len(), &label, SortParams::paper_1e7());
        let out = svc.submit_request(SortRequest::new(data)).wait().unwrap();
        assert_eq!(out.params, SortParams::paper_1e7());
        assert_eq!(svc.metrics().counter(names::PARAMS_CACHE_HIT), 1);
        // 3. explicit override wins.
        let custom = SortParams { tile: 777, ..SortParams::paper_1e7() };
        let req = SortRequest::new(generate_i64(200_000, Distribution::Uniform, 5, 2))
            .with_params(custom);
        let out = svc.submit_request(req).wait().unwrap();
        assert_eq!(out.params.tile, 777);
        assert_eq!(svc.metrics().counter(names::PARAMS_OVERRIDE), 1);
    }

    #[test]
    fn mislabeled_dist_cannot_poison_the_cache() {
        // Regression test for the PR-1 label-trust bug: the cache used to be
        // keyed on the caller-declared `dist` string, so parameters tuned
        // for one workload were served to *any* job claiming that label in
        // the same size band. Fingerprint keying puts mislabeled jobs in
        // their own class.
        let svc = service();
        let uniform = generate_i64(150_000, Distribution::Uniform, 7, 2);
        let sorted = generate_i64(150_000, Distribution::Sorted, 7, 2);
        let uniform_label = SortService::fingerprint_label(&uniform);
        let sorted_label = SortService::fingerprint_label(&sorted);
        assert_ne!(uniform_label, sorted_label, "shapes must land in different classes");

        // "Poison" the uniform class with pathological parameters.
        let poison = SortParams { tile: 64, insertion_threshold: 16, ..SortParams::paper_1e7() };
        svc.cache().put(uniform.len(), &uniform_label, poison);

        // A sorted-data job *claiming* to be uniform does not see them…
        let mislabeled = SortRequest::new(sorted).with_dist("uniform");
        let out = svc.submit_request(mislabeled).wait().unwrap();
        assert!(out.valid);
        assert_ne!(out.params, poison, "mislabeled job must not resolve through the uniform class");
        assert_eq!(svc.metrics().counter(names::PARAMS_CACHE_HIT), 0);

        // …while genuinely uniform data still hits its class.
        let out = svc.submit_request(SortRequest::new(uniform)).wait().unwrap();
        assert_eq!(out.params, poison);
        assert_eq!(svc.metrics().counter(names::PARAMS_CACHE_HIT), 1);
    }

    #[test]
    fn dtype_classes_do_not_collide_in_the_cache() {
        // The same shape as i64 and as f64 resolves through different
        // dtype-tagged classes: poisoning one leaves the other cold.
        let svc = service();
        let ints = generate_i64(150_000, Distribution::Uniform, 8, 2);
        let floats: Vec<f64> = ints.iter().map(|&x| x as f64).collect();
        let int_label = SortService::fingerprint_label(&ints);
        let float_label = SortService::fingerprint_label_for(&floats);
        assert_ne!(int_label, float_label);
        assert!(float_label.ends_with(":f64"), "{float_label}");

        let poison = SortParams { tile: 64, ..SortParams::paper_1e7() };
        svc.cache().put(ints.len(), &int_label, poison);
        let out = svc.submit_request(SortRequest::new(floats)).wait().unwrap();
        assert!(out.valid);
        assert_ne!(out.params, poison, "f64 must not resolve through the i64 class");
        assert_eq!(svc.metrics().counter(names::PARAMS_CACHE_HIT), 0);
        let out = svc.submit_request(SortRequest::new(ints)).wait().unwrap();
        assert_eq!(out.params, poison);
    }

    #[test]
    fn drain_waits_for_all() {
        let svc = service();
        for seed in 0..5u64 {
            // Fire-and-forget: drop the tickets.
            let data = generate_i64(20_000, Distribution::Uniform, seed, 2);
            let _ = svc.submit_request(SortRequest::new(data));
        }
        svc.drain();
        assert_eq!(svc.metrics().counter(names::JOBS_COMPLETED), 5);
        assert!(svc.drain_timeout(Duration::from_millis(50)), "idle drain returns immediately");
    }

    #[test]
    fn skip_validation_path() {
        let svc = service();
        let data = generate_i64(50_000, Distribution::Uniform, 9, 2);
        let req = SortRequest::new(data).without_validation();
        let out = svc.submit_request(req).wait().unwrap();
        assert!(out.valid, "unvalidated jobs report valid=true");
        assert!(sorted_i64(&out).windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn ticket_try_result_and_wait_timeout() {
        let svc = service();
        let data = generate_i64(80_000, Distribution::Uniform, 10, 2);
        // Poll until done, then extract exactly once.
        let mut ticket = svc.submit_request(SortRequest::new(data));
        let out = loop {
            match ticket.try_result() {
                Ok(result) => break result.expect("job ok"),
                Err(pending) => {
                    ticket = match pending.wait_timeout(Duration::from_millis(20)) {
                        Ok(result) => break result.expect("job ok"),
                        Err(t) => t,
                    };
                }
            }
        };
        assert!(out.valid);
    }

    #[test]
    fn cancel_queued_job_resolves_cancelled() {
        // One worker, deep queue: occupy the worker with slow jobs so a
        // later job is still queued when we cancel it.
        let svc = SortService::new(ServiceConfig::sized(1, 1, 16));
        let blockers: Vec<Ticket> = (0..3)
            .map(|s| {
                let data = generate_i64(400_000, Distribution::Uniform, s, 1);
                svc.submit_request(SortRequest::new(data))
            })
            .collect();
        let victim_data = generate_i64(400_000, Distribution::Uniform, 99, 1);
        let victim = svc.submit_request(SortRequest::new(victim_data));
        let requested = victim.cancel();
        let result = victim.wait();
        if requested {
            // `cancel() == true` is a hard guarantee: the job was still
            // queued, so it must resolve cancelled without sorting.
            assert_eq!(result.unwrap_err(), JobError::Cancelled);
        } else {
            assert!(result.is_ok(), "a job that already started completes normally");
        }
        for b in blockers {
            assert!(b.wait().is_ok());
        }
    }

    #[test]
    fn batch_sorts_everything_in_order() {
        let svc = service();
        let requests: Vec<SortRequest> = (0..24u64)
            .map(|seed| {
                let n = 5_000 + (seed as usize * 379) % 20_000;
                SortRequest::new(generate_i64(n, Distribution::Uniform, seed, 2))
            })
            .collect();
        let expected: Vec<Vec<i64>> = requests
            .iter()
            .map(|r| {
                let mut v = r.payload().as_slice::<i64>().unwrap().to_vec();
                v.sort_unstable();
                v
            })
            .collect();
        let report = svc.submit_batch_requests(requests).wait();
        assert_eq!(report.outcomes.len(), 24);
        assert_eq!(report.stats.jobs, 24);
        assert_eq!(report.stats.invalid, 0);
        assert_eq!(report.stats.failed, 0);
        for (i, want) in expected.iter().enumerate() {
            let out = report.output(i);
            assert!(out.valid);
            assert_eq!(out.data::<i64>().unwrap(), &want[..], "submission order");
        }
        // Unique ids across the batch.
        let ids: std::collections::HashSet<u64> = report.outputs().map(|o| o.id).collect();
        assert_eq!(ids.len(), 24);
        // Stats are consistent.
        assert!(report.stats.p50_secs <= report.stats.p99_secs);
        assert!(report.stats.jobs_per_sec > 0.0);
        assert_eq!(report.stats.elements, expected.iter().map(|v| v.len() as u64).sum::<u64>());
        assert_eq!(report.stats.per_dtype.len(), 1);
        assert_eq!(report.stats.per_dtype[0].dtype, Dtype::I64);
        assert_eq!(report.stats.per_dtype[0].jobs, 24);
        // Metrics published.
        assert_eq!(svc.metrics().counter(names::BATCH_JOBS_SUBMITTED), 24);
        assert_eq!(svc.metrics().counter(names::BATCH_COMPLETED), 1);
        assert_eq!(svc.metrics().counter(names::JOBS_COMPLETED), 24);
        assert!(svc.metrics().gauge(names::BATCH_LAST_JOBS_PER_SEC).unwrap() > 0.0);
        assert!(svc.metrics().percentile(names::BATCH_JOB_LATENCY, 99.0).is_some());
    }

    #[test]
    fn batch_edge_cases_empty_and_tiny() {
        let svc = service();
        // Empty batch.
        let report = svc.submit_batch_requests(Vec::new()).wait();
        assert!(report.outcomes.is_empty());
        assert_eq!(report.stats.jobs_per_sec, 0.0);
        assert_eq!(report.stats.p99_secs, 0.0);
        assert!(report.stats.per_dtype.is_empty());
        // Batch containing empty-slice and single-element jobs, mixed dtypes.
        let requests = vec![
            SortRequest::new(Vec::<i64>::new()),
            SortRequest::new(vec![7i64]),
            SortRequest::new(vec![3i64, -1]),
            SortRequest::new(vec![2.5f64, -0.5]),
            SortRequest::new(vec![9u64, 4]),
        ];
        let report = svc.submit_batch_requests(requests).wait();
        assert_eq!(report.output(0).data::<i64>().unwrap(), &[] as &[i64]);
        assert_eq!(report.output(1).data::<i64>().unwrap(), &[7]);
        assert_eq!(report.output(2).data::<i64>().unwrap(), &[-1, 3]);
        assert_eq!(report.output(3).data::<f64>().unwrap(), &[-0.5, 2.5]);
        assert_eq!(report.output(4).data::<u64>().unwrap(), &[4, 9]);
        assert!(report.outputs().all(|o| o.valid));
        assert_eq!(report.stats.per_dtype.len(), 3);
    }

    #[test]
    fn batch_respects_param_override_and_cache() {
        let svc = service();
        let cached_data = generate_i64(120_000, Distribution::Uniform, 2, 2);
        svc.cache().put(
            cached_data.len(),
            &SortService::fingerprint_label(&cached_data),
            SortParams::paper_1e8(),
        );
        let override_req = SortRequest::new(generate_i64(120_000, Distribution::Uniform, 1, 2))
            .with_params(SortParams { tile: 333, ..SortParams::paper_1e7() });
        let cached_req = SortRequest::new(cached_data);
        let report = svc.submit_batch_requests(vec![override_req, cached_req]).wait();
        assert_eq!(report.output(0).params.tile, 333);
        assert_eq!(report.output(1).params, SortParams::paper_1e8());
        assert_eq!(svc.metrics().counter(names::PARAMS_OVERRIDE), 1);
        assert_eq!(svc.metrics().counter(names::PARAMS_CACHE_HIT), 1);
        // The batch report carries its own hit/miss accounting (overrides
        // count as neither).
        assert_eq!(report.stats.cache_hits, 1);
        assert_eq!(report.stats.cache_misses, 0);
    }

    #[test]
    fn stream_yields_in_submission_order_without_barrier() {
        // One worker: jobs execute in submission order, so the first (tiny)
        // job finishes while the remaining (large) jobs are still queued —
        // the stream must hand it over before the batch completes.
        let svc = SortService::new(ServiceConfig::sized(1, 2, 16));
        let tiny = generate_i64(1_000, Distribution::Uniform, 0, 2);
        let mut requests = vec![SortRequest::new(tiny)];
        for seed in 1..6u64 {
            let large = generate_i64(400_000, Distribution::Uniform, seed, 2);
            requests.push(SortRequest::new(large));
        }
        let total = requests.len() as u64;
        let mut stream = svc.submit_batch_requests(requests).stream();
        assert_eq!(stream.remaining(), total as usize);
        let first = stream.next().expect("stream has items").expect("job ok");
        assert_eq!(first.len(), 1_000, "first yield is the first-submitted job");
        let completed_at_first_yield = svc.metrics().counter(names::JOBS_COMPLETED);
        assert!(
            completed_at_first_yield < total,
            "first result must arrive before the whole batch completes \
             (completed {completed_at_first_yield}/{total})"
        );
        let rest: Vec<JobResult> = stream.collect();
        assert_eq!(rest.len(), total as usize - 1);
        for (i, r) in rest.iter().enumerate() {
            let out = r.as_ref().expect("job ok");
            assert_eq!(out.len(), 400_000, "order: item {i}");
            assert!(out.valid);
        }
        assert_eq!(svc.metrics().counter(names::BATCH_COMPLETED), 1);
    }

    #[test]
    fn result_stream_survives_lost_senders() {
        // Synthesize a batch whose workers vanished mid-way: the stream must
        // resolve the missing tail as WorkerLost instead of hanging.
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let out = |id: u64| SortOutput {
            id,
            payload: SortPayload::I64(vec![1]),
            params: SortParams::default(),
            secs: 0.0,
            valid: true,
        };
        tx.send((1usize, Ok(out(11)))).unwrap(); // out of order
        tx.send((0usize, Ok(out(10)))).unwrap();
        drop(tx); // jobs 2 and 3 never report
        let stream = ResultStream {
            rx,
            buffered: HashMap::new(),
            next_idx: 0,
            total: 4,
            completion: BatchCompletion { metrics, published: false },
        };
        let got: Vec<JobResult> = stream.collect();
        assert_eq!(got.len(), 4);
        assert_eq!(got[0].as_ref().unwrap().id, 10);
        assert_eq!(got[1].as_ref().unwrap().id, 11);
        assert_eq!(*got[2].as_ref().unwrap_err(), JobError::WorkerLost);
        assert_eq!(*got[3].as_ref().unwrap_err(), JobError::WorkerLost);
    }

    #[test]
    fn dropped_batch_ticket_still_closes_the_counter_pair() {
        let svc = service();
        let requests: Vec<SortRequest> = (0..3u64)
            .map(|s| SortRequest::new(generate_i64(10_000, Distribution::Uniform, s, 2)))
            .collect();
        let ticket = svc.submit_batch_requests(requests);
        drop(ticket); // fire-and-forget
        svc.drain();
        assert_eq!(svc.metrics().counter(names::JOBS_COMPLETED), 3);
        assert_eq!(svc.metrics().counter(names::BATCH_SUBMITTED), 1);
        assert_eq!(svc.metrics().counter(names::BATCH_COMPLETED), 1);
    }

    #[test]
    fn batch_wait_survives_lost_senders() {
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        tx.send((
            0usize,
            Ok(SortOutput {
                id: 1,
                payload: SortPayload::F64(vec![1.0]),
                params: SortParams::default(),
                secs: 0.001,
                valid: true,
            }),
        ))
        .unwrap();
        drop(tx);
        let ticket = BatchTicket {
            total: 3,
            started: Instant::now(),
            rx,
            completion: BatchCompletion { metrics, published: false },
            cache_hits: Arc::new(AtomicU64::new(0)),
            cache_misses: Arc::new(AtomicU64::new(0)),
        };
        let report = ticket.wait();
        assert_eq!(report.stats.jobs, 3);
        assert_eq!(report.stats.failed, 2);
        assert_eq!(report.stats.per_dtype.len(), 1);
        assert_eq!(report.stats.per_dtype[0].dtype, Dtype::F64);
    }

    fn spill_root(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("evosort-svc-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn spill_dirs_left(root: &std::path::Path) -> usize {
        std::fs::read_dir(root).map(|rd| rd.count()).unwrap_or(0)
    }

    fn external_service(budget: usize, root: &std::path::Path) -> SortService {
        SortService::new(
            ServiceConfig::sized(2, 2, 8)
                .with_external(ExternalConfig::new(budget).with_spill_dir(root.to_path_buf())),
        )
    }

    #[test]
    fn oversized_job_escalates_and_sorts_via_spill() {
        let root = spill_root("escalate");
        let svc = external_service(1 << 20, &root); // 1 MiB budget
        let data = generate_i64(200_000, Distribution::Zipf, 31, 2); // 1.6 MiB payload
        let mut expect = data.clone();
        expect.sort_unstable();
        let out = svc.submit_request(SortRequest::new(data)).wait().expect("job ok");
        assert!(out.valid, "escalated sort must survive multiset validation");
        assert_eq!(sorted_i64(&out), expect);
        assert_eq!(svc.metrics().counter(names::EXTSORT_JOBS), 1);
        assert!(
            svc.metrics().counter(names::EXTSORT_RUNS_SPILLED) >= 3,
            "a 1.6 MiB job under a 1 MiB budget spills several runs"
        );
        assert_eq!(svc.metrics().counter(names::JOBS_COMPLETED), 1);
        assert_eq!(svc.metrics().counter(names::JOBS_INVALID), 0);
        assert_eq!(spill_dirs_left(&root), 0, "spill directories must be cleaned up");
        // A small job under the same config stays on the in-RAM path.
        let small = generate_i64(10_000, Distribution::Uniform, 32, 2);
        let out = svc.submit_request(SortRequest::new(small)).wait().expect("job ok");
        assert!(out.valid);
        assert_eq!(svc.metrics().counter(names::EXTSORT_JOBS), 1, "small job must not escalate");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn external_streaming_chunks_reassemble_the_sorted_payload() {
        let root = spill_root("stream");
        let svc = external_service(1 << 20, &root);
        let data = generate_i64(200_000, Distribution::Uniform, 33, 2);
        let mut expect = data.clone();
        expect.sort_unstable();
        let ticket = svc.submit_external_streaming(SortRequest::new(data));
        let total = ticket.len();
        assert!(total > 1, "a beyond-budget job streams multiple chunks");
        let mut got: Vec<i64> = Vec::new();
        let mut chunks = 0usize;
        for r in ticket.stream() {
            let out = r.expect("chunk ok");
            got.extend_from_slice(out.data::<i64>().unwrap());
            chunks += 1;
        }
        assert_eq!(chunks, total, "ticket length is the chunk-count contract");
        assert_eq!(got, expect, "chunk concatenation is the sorted payload");
        svc.drain();
        assert_eq!(svc.metrics().counter(names::EXTSORT_CHUNKS_STREAMED), total as u64);
        assert_eq!(svc.metrics().counter(names::JOBS_COMPLETED), 1);
        assert_eq!(spill_dirs_left(&root), 0);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn escalated_jobs_resolve_through_the_beyond_memory_class() {
        use crate::autotune::fingerprint::beyond_memory_label;
        let root = spill_root("xmclass");
        let svc = external_service(512 * 1024, &root);
        let data = generate_i64(120_000, Distribution::Uniform, 34, 2); // 960 KiB
        let xm = beyond_memory_label(&SortService::fingerprint_label(&data));
        assert!(xm.ends_with(":xm"), "{xm}");
        let tuned_ext = ExtParams { run_size: 30_000, merge_fan_in: 4, spill_threshold: 0 };
        svc.cache().put_ext_with_fitness(
            data.len(),
            &xm,
            SortParams::paper_1e8(),
            tuned_ext,
            0.1,
        );
        let out = svc.submit_request(SortRequest::new(data)).wait().expect("job ok");
        assert!(out.valid);
        assert_eq!(
            out.params,
            SortParams::paper_1e8(),
            "sort params resolve through the :xm class"
        );
        assert_eq!(svc.metrics().counter(names::PARAMS_CACHE_HIT), 1);
        // The tuned run size drives the spill layout: ceil(120k / 30k) runs.
        assert_eq!(svc.metrics().counter(names::EXTSORT_RUNS_SPILLED), 4);
        assert_eq!(spill_dirs_left(&root), 0);
        let _ = std::fs::remove_dir_all(&root);
    }
}
