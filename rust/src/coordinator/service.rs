//! The sort service: EvoSort as a long-running coordinator.
//!
//! Clients submit [`SortJob`]s; a bounded [`ThreadPool`](crate::exec::pool::ThreadPool)
//! executes them (backpressure when the queue fills), each job resolving its
//! parameters from — in priority order — the explicit override, the tuning
//! cache, or the symbolic model, then running Adaptive Partition Sort and
//! validating the output. Results come back over a per-job channel.
//!
//! Two submission paths share one execution helper:
//!
//! * [`SortService::submit`] — one job, one pool task, one reply channel
//!   (lowest latency for sparse traffic);
//! * [`SortService::submit_batch`] — many jobs in one call: the batch is
//!   sharded across the pool via a shared work queue (dynamic balancing —
//!   a shard that drew small jobs keeps pulling), each worker reuses one
//!   radix scratch buffer across all the jobs it executes, and the returned
//!   [`BatchReport`] carries p50/p99 latency and jobs/sec, which are also
//!   published through [`Metrics`] (`batch.*` gauges and sample windows).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::coordinator::metrics::{self, Metrics};
use crate::coordinator::tuning_cache::TuningCache;
use crate::data::validate::{self, Verdict};
use crate::params::SortParams;
use crate::sort::AdaptiveSorter;
use crate::symbolic::SymbolicModel;
use crate::util::timer;

/// A sorting request.
pub struct SortJob {
    pub data: Vec<i64>,
    /// Workload tag used for cache lookup ("uniform", "zipf", ...).
    pub dist: String,
    /// Explicit parameter override (skips cache + model).
    pub params: Option<SortParams>,
    /// Validate the output before returning (adds one parallel pass).
    pub validate: bool,
}

impl SortJob {
    pub fn new(data: Vec<i64>) -> Self {
        SortJob { data, dist: "uniform".into(), params: None, validate: true }
    }
}

/// A completed job.
#[derive(Debug)]
pub struct SortOutcome {
    pub id: u64,
    pub data: Vec<i64>,
    pub params: SortParams,
    pub secs: f64,
    pub valid: bool,
}

/// Handle to an in-flight job.
pub struct JobHandle {
    pub id: u64,
    rx: mpsc::Receiver<SortOutcome>,
}

impl JobHandle {
    /// Block until the job completes.
    pub fn wait(self) -> SortOutcome {
        self.rx.recv().expect("service dropped job reply")
    }
}

/// Aggregate statistics for one completed batch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchStats {
    pub jobs: usize,
    pub invalid: usize,
    /// Total elements sorted across the batch.
    pub elements: u64,
    /// Batch throughput: jobs / wall-clock seconds.
    pub jobs_per_sec: f64,
    /// Median per-job sort latency (nearest rank).
    pub p50_secs: f64,
    /// 99th-percentile per-job sort latency (nearest rank).
    pub p99_secs: f64,
    pub mean_secs: f64,
}

impl BatchStats {
    fn compute(outcomes: &[SortOutcome], wall_secs: f64) -> BatchStats {
        let jobs = outcomes.len();
        let invalid = outcomes.iter().filter(|o| !o.valid).count();
        let elements = outcomes.iter().map(|o| o.data.len() as u64).sum();
        let mut lats: Vec<f64> = outcomes.iter().map(|o| o.secs).collect();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let (p50_secs, p99_secs, mean_secs) = if lats.is_empty() {
            (0.0, 0.0, 0.0)
        } else {
            (
                metrics::percentile_of_sorted(&lats, 50.0),
                metrics::percentile_of_sorted(&lats, 99.0),
                lats.iter().sum::<f64>() / jobs as f64,
            )
        };
        let jobs_per_sec = if wall_secs > 0.0 { jobs as f64 / wall_secs } else { 0.0 };
        BatchStats { jobs, invalid, elements, jobs_per_sec, p50_secs, p99_secs, mean_secs }
    }
}

/// The result of one batch: outcomes in submission order plus throughput and
/// latency-percentile statistics.
#[derive(Debug)]
pub struct BatchReport {
    pub outcomes: Vec<SortOutcome>,
    pub wall_secs: f64,
    pub stats: BatchStats,
}

/// Handle to an in-flight batch.
pub struct BatchHandle {
    total: usize,
    started: Instant,
    rx: mpsc::Receiver<(usize, SortOutcome)>,
    metrics: Arc<Metrics>,
}

impl BatchHandle {
    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Block until every job in the batch completes; outcomes are returned in
    /// submission order and the batch gauges are published to the metrics
    /// registry (`batch.last.*`).
    pub fn wait(self) -> BatchReport {
        let mut slots: Vec<Option<SortOutcome>> = (0..self.total).map(|_| None).collect();
        for _ in 0..self.total {
            let (idx, outcome) = self.rx.recv().expect("service dropped batch reply");
            slots[idx] = Some(outcome);
        }
        let wall_secs = self.started.elapsed().as_secs_f64();
        let outcomes: Vec<SortOutcome> =
            slots.into_iter().map(|s| s.expect("every job reports exactly once")).collect();
        let stats = BatchStats::compute(&outcomes, wall_secs);
        self.metrics.incr("batch.completed");
        self.metrics.set_gauge("batch.last.jobs_per_sec", stats.jobs_per_sec);
        self.metrics.set_gauge("batch.last.p50_secs", stats.p50_secs);
        self.metrics.set_gauge("batch.last.p99_secs", stats.p99_secs);
        BatchReport { outcomes, wall_secs, stats }
    }
}

/// Run one resolved job to completion: optional fingerprint, timed sort with
/// caller-provided scratch, validation, metrics accounting. Shared by the
/// single-job and batched submission paths.
fn execute_job(
    sorter: &AdaptiveSorter,
    metrics: &Metrics,
    id: u64,
    mut job: SortJob,
    params: SortParams,
    scratch: &mut Vec<i64>,
) -> SortOutcome {
    let threads = sorter.threads();
    let fp = job.validate.then(|| validate::fingerprint_i64(&job.data, threads));
    let (_, secs) = timer::time(|| sorter.sort_i64_with_scratch(&mut job.data, &params, scratch));
    let valid = match fp {
        Some(fp) => validate::validate_i64(fp, &job.data, threads) == Verdict::Valid,
        None => true,
    };
    metrics.incr("jobs.completed");
    metrics.observe("sort.latency", secs);
    metrics.add("elements.sorted", job.data.len() as u64);
    if !valid {
        metrics.incr("jobs.invalid");
    }
    SortOutcome { id, data: job.data, params, secs, valid }
}

/// Service configuration.
pub struct ServiceConfig {
    /// Concurrent sort jobs (each job internally uses `sort_threads`).
    pub workers: usize,
    /// Threads each sort uses.
    pub sort_threads: usize,
    /// Pending-job queue bound (backpressure).
    pub queue_capacity: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        let hw = crate::util::default_threads();
        ServiceConfig { workers: 2, sort_threads: hw.div_ceil(2), queue_capacity: 64 }
    }
}

/// The coordinator service.
pub struct SortService {
    pool: crate::exec::pool::ThreadPool,
    sorter: Arc<AdaptiveSorter>,
    cache: Arc<TuningCache>,
    model: SymbolicModel,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

impl SortService {
    pub fn new(config: ServiceConfig) -> Self {
        Self::with_sorter(config, AdaptiveSorter::new(1))
    }

    /// Build with a prepared sorter (e.g. XLA backend attached). The sorter's
    /// thread budget is replaced by `config.sort_threads`.
    pub fn with_sorter(config: ServiceConfig, sorter: AdaptiveSorter) -> Self {
        let sorter = sorter.rebudget(config.sort_threads);
        SortService {
            pool: crate::exec::pool::ThreadPool::with_capacity(
                config.workers,
                config.queue_capacity,
            ),
            sorter: Arc::new(sorter),
            cache: Arc::new(TuningCache::new()),
            model: SymbolicModel::paper(),
            metrics: Arc::new(Metrics::new()),
            next_id: AtomicU64::new(1),
        }
    }

    /// Replace the symbolic model (e.g. one fitted on this machine).
    pub fn set_model(&mut self, model: SymbolicModel) {
        self.model = model;
    }

    pub fn cache(&self) -> &Arc<TuningCache> {
        &self.cache
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Resolve parameters for a job: override → cache → symbolic model.
    fn resolve_params(&self, job: &SortJob) -> SortParams {
        if let Some(p) = job.params {
            self.metrics.incr("params.override");
            return p;
        }
        if let Some(p) = self.cache.get(job.data.len(), &job.dist) {
            self.metrics.incr("params.cache_hit");
            return p;
        }
        self.metrics.incr("params.symbolic");
        self.model.params_for(job.data.len())
    }

    /// Submit a job; blocks only when the queue is full (backpressure).
    pub fn submit(&self, job: SortJob) -> JobHandle {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let sorter = Arc::clone(&self.sorter);
        let metrics = Arc::clone(&self.metrics);
        let params = self.resolve_params(&job);
        self.metrics.incr("jobs.submitted");
        let submitted = self.pool.submit(move || {
            let outcome = execute_job(&sorter, &metrics, id, job, params, &mut Vec::new());
            let _ = tx.send(outcome);
        });
        assert!(submitted, "service is shutting down");
        JobHandle { id, rx }
    }

    /// Submit a whole batch of jobs in one call.
    ///
    /// Parameters are resolved up front on the caller thread (cache/model
    /// lookups are cheap); the jobs then flow through a shared work queue
    /// drained by up to `pool.threads()` pool tasks, so shards balance
    /// dynamically under mixed job sizes and every shard reuses a single
    /// radix scratch buffer across all the jobs it executes — the
    /// `sort_i64_with_scratch` hot path allocates nothing after the first
    /// large job. Per-job latencies stream into the `batch.job.latency`
    /// sample window; [`BatchHandle::wait`] publishes p50/p99/jobs-per-sec.
    pub fn submit_batch(&self, jobs: Vec<SortJob>) -> BatchHandle {
        let started = Instant::now();
        let total = jobs.len();
        let (tx, rx) = mpsc::channel();
        // Keep the shared counters consistent with the single-job path
        // (jobs.submitted >= jobs.completed must hold across mixed traffic).
        self.metrics.add("jobs.submitted", total as u64);
        self.metrics.add("batch.jobs.submitted", total as u64);
        self.metrics.incr("batch.submitted");
        let queue: VecDeque<(usize, u64, SortJob, SortParams)> = jobs
            .into_iter()
            .enumerate()
            .map(|(idx, job)| {
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                let params = self.resolve_params(&job);
                (idx, id, job, params)
            })
            .collect();
        let queue = Arc::new(Mutex::new(queue));
        let shards = self.pool.threads().min(total.max(1));
        for _ in 0..shards {
            let queue = Arc::clone(&queue);
            let sorter = Arc::clone(&self.sorter);
            let metrics = Arc::clone(&self.metrics);
            let tx = tx.clone();
            let submitted = self.pool.submit(move || {
                // Per-shard scratch, reused across every job this shard pulls.
                let mut scratch: Vec<i64> = Vec::new();
                loop {
                    let item = queue.lock().unwrap().pop_front();
                    let Some((idx, id, job, params)) = item else { break };
                    let outcome = execute_job(&sorter, &metrics, id, job, params, &mut scratch);
                    metrics.observe_sample("batch.job.latency", outcome.secs);
                    let _ = tx.send((idx, outcome));
                }
            });
            assert!(submitted, "service is shutting down");
        }
        BatchHandle { total, started, rx, metrics: Arc::clone(&self.metrics) }
    }

    /// Block until every submitted job has completed.
    pub fn drain(&self) {
        self.pool.wait_idle();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_i64, Distribution};

    fn service() -> SortService {
        SortService::new(ServiceConfig { workers: 2, sort_threads: 2, queue_capacity: 8 })
    }

    #[test]
    fn submit_and_wait_sorted() {
        let svc = service();
        let data = generate_i64(150_000, Distribution::Uniform, 1, 2);
        let mut expect = data.clone();
        expect.sort_unstable();
        let out = svc.submit(SortJob::new(data)).wait();
        assert!(out.valid);
        assert_eq!(out.data, expect);
        assert!(out.secs > 0.0);
        assert_eq!(svc.metrics().counter("jobs.completed"), 1);
    }

    #[test]
    fn many_concurrent_jobs() {
        let svc = service();
        let handles: Vec<JobHandle> = (0..10u64)
            .map(|seed| {
                let data = generate_i64(30_000, Distribution::Uniform, seed, 2);
                svc.submit(SortJob::new(data))
            })
            .collect();
        let mut ids = std::collections::HashSet::new();
        for h in handles {
            let out = h.wait();
            assert!(out.valid);
            assert!(out.data.windows(2).all(|w| w[0] <= w[1]));
            ids.insert(out.id);
        }
        assert_eq!(ids.len(), 10, "unique job ids");
        assert_eq!(svc.metrics().counter("jobs.completed"), 10);
        assert_eq!(svc.metrics().counter("jobs.invalid"), 0);
    }

    #[test]
    fn params_resolution_order() {
        let svc = service();
        // 1. symbolic (cold cache).
        let out = svc.submit(SortJob::new(generate_i64(200_000, Distribution::Uniform, 3, 2))).wait();
        assert!(out.valid);
        assert_eq!(svc.metrics().counter("params.symbolic"), 1);
        // 2. cache hit after put.
        svc.cache().put(200_000, "uniform", SortParams::paper_1e7());
        let out = svc.submit(SortJob::new(generate_i64(200_000, Distribution::Uniform, 4, 2))).wait();
        assert_eq!(out.params, SortParams::paper_1e7());
        assert_eq!(svc.metrics().counter("params.cache_hit"), 1);
        // 3. explicit override wins.
        let mut job = SortJob::new(generate_i64(200_000, Distribution::Uniform, 5, 2));
        let custom = SortParams { tile: 777, ..SortParams::paper_1e7() };
        job.params = Some(custom);
        let out = svc.submit(job).wait();
        assert_eq!(out.params.tile, 777);
        assert_eq!(svc.metrics().counter("params.override"), 1);
    }

    #[test]
    fn drain_waits_for_all() {
        let svc = service();
        for seed in 0..5u64 {
            // Fire-and-forget: drop the handles.
            let _ = svc.submit(SortJob::new(generate_i64(20_000, Distribution::Uniform, seed, 2)));
        }
        svc.drain();
        assert_eq!(svc.metrics().counter("jobs.completed"), 5);
    }

    #[test]
    fn skip_validation_path() {
        let svc = service();
        let mut job = SortJob::new(generate_i64(50_000, Distribution::Uniform, 9, 2));
        job.validate = false;
        let out = svc.submit(job).wait();
        assert!(out.valid, "unvalidated jobs report valid=true");
        assert!(out.data.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn batch_sorts_everything_in_order() {
        let svc = service();
        let jobs: Vec<SortJob> = (0..24u64)
            .map(|seed| SortJob::new(generate_i64(5_000 + (seed as usize * 379) % 20_000, Distribution::Uniform, seed, 2)))
            .collect();
        let expected: Vec<Vec<i64>> = jobs
            .iter()
            .map(|j| {
                let mut v = j.data.clone();
                v.sort_unstable();
                v
            })
            .collect();
        let report = svc.submit_batch(jobs).wait();
        assert_eq!(report.outcomes.len(), 24);
        assert_eq!(report.stats.jobs, 24);
        assert_eq!(report.stats.invalid, 0);
        for (out, want) in report.outcomes.iter().zip(&expected) {
            assert!(out.valid);
            assert_eq!(&out.data, want, "batch outcomes must keep submission order");
        }
        // Unique ids across the batch.
        let ids: std::collections::HashSet<u64> = report.outcomes.iter().map(|o| o.id).collect();
        assert_eq!(ids.len(), 24);
        // Stats are consistent.
        assert!(report.stats.p50_secs <= report.stats.p99_secs);
        assert!(report.stats.jobs_per_sec > 0.0);
        assert_eq!(
            report.stats.elements,
            expected.iter().map(|v| v.len() as u64).sum::<u64>()
        );
        // Metrics published.
        assert_eq!(svc.metrics().counter("batch.jobs.submitted"), 24);
        assert_eq!(svc.metrics().counter("batch.completed"), 1);
        assert_eq!(svc.metrics().counter("jobs.completed"), 24);
        assert!(svc.metrics().gauge("batch.last.jobs_per_sec").unwrap() > 0.0);
        assert!(svc.metrics().percentile("batch.job.latency", 99.0).is_some());
    }

    #[test]
    fn batch_edge_cases_empty_and_tiny() {
        let svc = service();
        // Empty batch.
        let report = svc.submit_batch(Vec::new()).wait();
        assert!(report.outcomes.is_empty());
        assert_eq!(report.stats.jobs_per_sec, 0.0);
        assert_eq!(report.stats.p99_secs, 0.0);
        // Batch containing empty-slice and single-element jobs.
        let jobs = vec![
            SortJob::new(vec![]),
            SortJob::new(vec![7]),
            SortJob::new(vec![3, -1]),
        ];
        let report = svc.submit_batch(jobs).wait();
        assert_eq!(report.outcomes[0].data, Vec::<i64>::new());
        assert_eq!(report.outcomes[1].data, vec![7]);
        assert_eq!(report.outcomes[2].data, vec![-1, 3]);
        assert!(report.outcomes.iter().all(|o| o.valid));
    }

    #[test]
    fn batch_respects_param_override_and_cache() {
        let svc = service();
        svc.cache().put(120_000, "uniform", SortParams::paper_1e8());
        let mut override_job = SortJob::new(generate_i64(120_000, Distribution::Uniform, 1, 2));
        override_job.params = Some(SortParams { tile: 333, ..SortParams::paper_1e7() });
        let cached_job = SortJob::new(generate_i64(120_000, Distribution::Uniform, 2, 2));
        let report = svc.submit_batch(vec![override_job, cached_job]).wait();
        assert_eq!(report.outcomes[0].params.tile, 333);
        assert_eq!(report.outcomes[1].params, SortParams::paper_1e8());
        assert_eq!(svc.metrics().counter("params.override"), 1);
        assert_eq!(svc.metrics().counter("params.cache_hit"), 1);
    }
}
