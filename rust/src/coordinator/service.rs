//! The sort service: EvoSort as a long-running coordinator.
//!
//! Clients submit [`SortJob`]s; a bounded [`ThreadPool`](crate::exec::pool::ThreadPool)
//! executes them (backpressure when the queue fills), each job resolving its
//! parameters from — in priority order — the explicit override, the tuning
//! cache, or the symbolic model, then running Adaptive Partition Sort and
//! validating the output. Results come back over a per-job channel.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::tuning_cache::TuningCache;
use crate::data::validate::{self, Verdict};
use crate::params::SortParams;
use crate::sort::AdaptiveSorter;
use crate::symbolic::SymbolicModel;
use crate::util::timer;

/// A sorting request.
pub struct SortJob {
    pub data: Vec<i64>,
    /// Workload tag used for cache lookup ("uniform", "zipf", ...).
    pub dist: String,
    /// Explicit parameter override (skips cache + model).
    pub params: Option<SortParams>,
    /// Validate the output before returning (adds one parallel pass).
    pub validate: bool,
}

impl SortJob {
    pub fn new(data: Vec<i64>) -> Self {
        SortJob { data, dist: "uniform".into(), params: None, validate: true }
    }
}

/// A completed job.
#[derive(Debug)]
pub struct SortOutcome {
    pub id: u64,
    pub data: Vec<i64>,
    pub params: SortParams,
    pub secs: f64,
    pub valid: bool,
}

/// Handle to an in-flight job.
pub struct JobHandle {
    pub id: u64,
    rx: mpsc::Receiver<SortOutcome>,
}

impl JobHandle {
    /// Block until the job completes.
    pub fn wait(self) -> SortOutcome {
        self.rx.recv().expect("service dropped job reply")
    }
}

/// Service configuration.
pub struct ServiceConfig {
    /// Concurrent sort jobs (each job internally uses `sort_threads`).
    pub workers: usize,
    /// Threads each sort uses.
    pub sort_threads: usize,
    /// Pending-job queue bound (backpressure).
    pub queue_capacity: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        let hw = crate::util::default_threads();
        ServiceConfig { workers: 2, sort_threads: hw.div_ceil(2), queue_capacity: 64 }
    }
}

/// The coordinator service.
pub struct SortService {
    pool: crate::exec::pool::ThreadPool,
    sorter: Arc<AdaptiveSorter>,
    cache: Arc<TuningCache>,
    model: SymbolicModel,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

impl SortService {
    pub fn new(config: ServiceConfig) -> Self {
        Self::with_sorter(config, AdaptiveSorter::new(1))
    }

    /// Build with a prepared sorter (e.g. XLA backend attached). The sorter's
    /// thread budget is replaced by `config.sort_threads`.
    pub fn with_sorter(config: ServiceConfig, sorter: AdaptiveSorter) -> Self {
        let sorter = sorter.rebudget(config.sort_threads);
        SortService {
            pool: crate::exec::pool::ThreadPool::with_capacity(
                config.workers,
                config.queue_capacity,
            ),
            sorter: Arc::new(sorter),
            cache: Arc::new(TuningCache::new()),
            model: SymbolicModel::paper(),
            metrics: Arc::new(Metrics::new()),
            next_id: AtomicU64::new(1),
        }
    }

    /// Replace the symbolic model (e.g. one fitted on this machine).
    pub fn set_model(&mut self, model: SymbolicModel) {
        self.model = model;
    }

    pub fn cache(&self) -> &Arc<TuningCache> {
        &self.cache
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Resolve parameters for a job: override → cache → symbolic model.
    fn resolve_params(&self, job: &SortJob) -> SortParams {
        if let Some(p) = job.params {
            self.metrics.incr("params.override");
            return p;
        }
        if let Some(p) = self.cache.get(job.data.len(), &job.dist) {
            self.metrics.incr("params.cache_hit");
            return p;
        }
        self.metrics.incr("params.symbolic");
        self.model.params_for(job.data.len())
    }

    /// Submit a job; blocks only when the queue is full (backpressure).
    pub fn submit(&self, mut job: SortJob) -> JobHandle {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let sorter = Arc::clone(&self.sorter);
        let metrics = Arc::clone(&self.metrics);
        let params = self.resolve_params(&job);
        self.metrics.incr("jobs.submitted");
        let submitted = self.pool.submit(move || {
            let threads = sorter.threads();
            let fp = job.validate.then(|| validate::fingerprint_i64(&job.data, threads));
            let (_, secs) = timer::time(|| sorter.sort_i64(&mut job.data, &params));
            let valid = match fp {
                Some(fp) => validate::validate_i64(fp, &job.data, threads) == Verdict::Valid,
                None => true,
            };
            metrics.incr("jobs.completed");
            metrics.observe("sort.latency", secs);
            metrics.add("elements.sorted", job.data.len() as u64);
            if !valid {
                metrics.incr("jobs.invalid");
            }
            let _ = tx.send(SortOutcome { id, data: job.data, params, secs, valid });
        });
        assert!(submitted, "service is shutting down");
        JobHandle { id, rx }
    }

    /// Block until every submitted job has completed.
    pub fn drain(&self) {
        self.pool.wait_idle();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_i64, Distribution};

    fn service() -> SortService {
        SortService::new(ServiceConfig { workers: 2, sort_threads: 2, queue_capacity: 8 })
    }

    #[test]
    fn submit_and_wait_sorted() {
        let svc = service();
        let data = generate_i64(150_000, Distribution::Uniform, 1, 2);
        let mut expect = data.clone();
        expect.sort_unstable();
        let out = svc.submit(SortJob::new(data)).wait();
        assert!(out.valid);
        assert_eq!(out.data, expect);
        assert!(out.secs > 0.0);
        assert_eq!(svc.metrics().counter("jobs.completed"), 1);
    }

    #[test]
    fn many_concurrent_jobs() {
        let svc = service();
        let handles: Vec<JobHandle> = (0..10u64)
            .map(|seed| {
                let data = generate_i64(30_000, Distribution::Uniform, seed, 2);
                svc.submit(SortJob::new(data))
            })
            .collect();
        let mut ids = std::collections::HashSet::new();
        for h in handles {
            let out = h.wait();
            assert!(out.valid);
            assert!(out.data.windows(2).all(|w| w[0] <= w[1]));
            ids.insert(out.id);
        }
        assert_eq!(ids.len(), 10, "unique job ids");
        assert_eq!(svc.metrics().counter("jobs.completed"), 10);
        assert_eq!(svc.metrics().counter("jobs.invalid"), 0);
    }

    #[test]
    fn params_resolution_order() {
        let svc = service();
        // 1. symbolic (cold cache).
        let out = svc.submit(SortJob::new(generate_i64(200_000, Distribution::Uniform, 3, 2))).wait();
        assert!(out.valid);
        assert_eq!(svc.metrics().counter("params.symbolic"), 1);
        // 2. cache hit after put.
        svc.cache().put(200_000, "uniform", SortParams::paper_1e7());
        let out = svc.submit(SortJob::new(generate_i64(200_000, Distribution::Uniform, 4, 2))).wait();
        assert_eq!(out.params, SortParams::paper_1e7());
        assert_eq!(svc.metrics().counter("params.cache_hit"), 1);
        // 3. explicit override wins.
        let mut job = SortJob::new(generate_i64(200_000, Distribution::Uniform, 5, 2));
        let custom = SortParams { tile: 777, ..SortParams::paper_1e7() };
        job.params = Some(custom);
        let out = svc.submit(job).wait();
        assert_eq!(out.params.tile, 777);
        assert_eq!(svc.metrics().counter("params.override"), 1);
    }

    #[test]
    fn drain_waits_for_all() {
        let svc = service();
        for seed in 0..5u64 {
            // Fire-and-forget: drop the handles.
            let _ = svc.submit(SortJob::new(generate_i64(20_000, Distribution::Uniform, seed, 2)));
        }
        svc.drain();
        assert_eq!(svc.metrics().counter("jobs.completed"), 5);
    }

    #[test]
    fn skip_validation_path() {
        let svc = service();
        let mut job = SortJob::new(generate_i64(50_000, Distribution::Uniform, 9, 2));
        job.validate = false;
        let out = svc.submit(job).wait();
        assert!(out.valid, "unvalidated jobs report valid=true");
        assert!(out.data.windows(2).all(|w| w[0] <= w[1]));
    }
}
