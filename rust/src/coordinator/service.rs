//! The sort service: EvoSort as a long-running coordinator.
//!
//! Clients submit [`SortJob`]s; a bounded [`ThreadPool`](crate::exec::pool::ThreadPool)
//! executes them (backpressure when the queue fills), each job resolving its
//! parameters from — in priority order — the explicit override, the tuning
//! cache, or the symbolic model, then running Adaptive Partition Sort and
//! validating the output. Results come back over a per-job channel.
//!
//! Two submission paths share one execution helper:
//!
//! * [`SortService::submit`] — one job, one pool task, one reply channel
//!   (lowest latency for sparse traffic);
//! * [`SortService::submit_batch`] — many jobs in one call: the batch is
//!   sharded across the pool via a shared work queue (dynamic balancing —
//!   a shard that drew small jobs keeps pulling), each worker reuses one
//!   radix scratch buffer across all the jobs it executes, and the returned
//!   [`BatchReport`] carries p50/p99 latency and jobs/sec, which are also
//!   published through [`Metrics`] (`batch.*` gauges and sample windows).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::autotune::policy::AutotunePolicy;
use crate::autotune::tuner::{Observation, OnlineTuner};
use crate::autotune::{fingerprint, Fingerprint};
use crate::coordinator::metrics::{self, Metrics};
use crate::coordinator::tuning_cache::TuningCache;
use crate::data::validate::{self, Verdict};
use crate::params::SortParams;
use crate::sort::AdaptiveSorter;
use crate::symbolic::SymbolicModel;
use crate::util::timer;

/// A sorting request.
pub struct SortJob {
    pub data: Vec<i64>,
    /// Caller-declared workload tag ("uniform", "zipf", ...). A **hint**
    /// only: parameter resolution keys the tuning cache on a fingerprint of
    /// the actual data (see [`crate::autotune::Fingerprint`]), so a
    /// mislabeled job can no longer poison the cache for its size band.
    pub dist: String,
    /// Explicit parameter override (skips cache + model).
    pub params: Option<SortParams>,
    /// Validate the output before returning (adds one parallel pass).
    pub validate: bool,
}

impl SortJob {
    pub fn new(data: Vec<i64>) -> Self {
        SortJob { data, dist: "uniform".into(), params: None, validate: true }
    }
}

/// A completed job.
#[derive(Debug)]
pub struct SortOutcome {
    pub id: u64,
    pub data: Vec<i64>,
    pub params: SortParams,
    pub secs: f64,
    pub valid: bool,
}

/// Handle to an in-flight job.
pub struct JobHandle {
    pub id: u64,
    rx: mpsc::Receiver<SortOutcome>,
}

impl JobHandle {
    /// Block until the job completes.
    pub fn wait(self) -> SortOutcome {
        self.rx.recv().expect("service dropped job reply")
    }
}

/// Aggregate statistics for one completed batch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchStats {
    pub jobs: usize,
    pub invalid: usize,
    /// Total elements sorted across the batch.
    pub elements: u64,
    /// Batch throughput: jobs / wall-clock seconds.
    pub jobs_per_sec: f64,
    /// Median per-job sort latency (nearest rank).
    pub p50_secs: f64,
    /// 99th-percentile per-job sort latency (nearest rank).
    pub p99_secs: f64,
    pub mean_secs: f64,
    /// Jobs in this batch whose parameters came from the tuning cache.
    pub cache_hits: u64,
    /// Jobs that fell through to the symbolic model (overrides count as
    /// neither hit nor miss).
    pub cache_misses: u64,
}

impl BatchStats {
    fn compute(
        outcomes: &[SortOutcome],
        wall_secs: f64,
        cache_hits: u64,
        cache_misses: u64,
    ) -> BatchStats {
        let jobs = outcomes.len();
        let invalid = outcomes.iter().filter(|o| !o.valid).count();
        let elements = outcomes.iter().map(|o| o.data.len() as u64).sum();
        let mut lats: Vec<f64> = outcomes.iter().map(|o| o.secs).collect();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let (p50_secs, p99_secs, mean_secs) = if lats.is_empty() {
            (0.0, 0.0, 0.0)
        } else {
            (
                metrics::percentile_of_sorted(&lats, 50.0),
                metrics::percentile_of_sorted(&lats, 99.0),
                lats.iter().sum::<f64>() / jobs as f64,
            )
        };
        let jobs_per_sec = if wall_secs > 0.0 { jobs as f64 / wall_secs } else { 0.0 };
        BatchStats {
            jobs,
            invalid,
            elements,
            jobs_per_sec,
            p50_secs,
            p99_secs,
            mean_secs,
            cache_hits,
            cache_misses,
        }
    }
}

/// The result of one batch: outcomes in submission order plus throughput and
/// latency-percentile statistics.
#[derive(Debug)]
pub struct BatchReport {
    pub outcomes: Vec<SortOutcome>,
    pub wall_secs: f64,
    pub stats: BatchStats,
}

/// Handle to an in-flight batch.
pub struct BatchHandle {
    total: usize,
    started: Instant,
    rx: mpsc::Receiver<(usize, SortOutcome)>,
    metrics: Arc<Metrics>,
    // Shards resolve params concurrently; each job's increment
    // happens-before its outcome lands on `rx`, so `wait` reads totals.
    cache_hits: Arc<AtomicU64>,
    cache_misses: Arc<AtomicU64>,
}

impl BatchHandle {
    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Block until every job in the batch completes; outcomes are returned in
    /// submission order and the batch gauges are published to the metrics
    /// registry (`batch.last.*`).
    pub fn wait(self) -> BatchReport {
        let mut slots: Vec<Option<SortOutcome>> = (0..self.total).map(|_| None).collect();
        for _ in 0..self.total {
            let (idx, outcome) = self.rx.recv().expect("service dropped batch reply");
            slots[idx] = Some(outcome);
        }
        let wall_secs = self.started.elapsed().as_secs_f64();
        let outcomes: Vec<SortOutcome> =
            slots.into_iter().map(|s| s.expect("every job reports exactly once")).collect();
        let stats = BatchStats::compute(
            &outcomes,
            wall_secs,
            self.cache_hits.load(Ordering::Relaxed),
            self.cache_misses.load(Ordering::Relaxed),
        );
        self.metrics.incr("batch.completed");
        self.metrics.set_gauge("batch.last.jobs_per_sec", stats.jobs_per_sec);
        self.metrics.set_gauge("batch.last.p50_secs", stats.p50_secs);
        self.metrics.set_gauge("batch.last.p99_secs", stats.p99_secs);
        BatchReport { outcomes, wall_secs, stats }
    }
}

/// Run one resolved job to completion: optional fingerprint, timed sort with
/// caller-provided scratch, validation, metrics accounting. Shared by the
/// single-job and batched submission paths.
fn execute_job(
    sorter: &AdaptiveSorter,
    metrics: &Metrics,
    id: u64,
    mut job: SortJob,
    params: SortParams,
    scratch: &mut Vec<i64>,
) -> SortOutcome {
    let threads = sorter.threads();
    let fp = job.validate.then(|| validate::fingerprint_i64(&job.data, threads));
    let (_, secs) = timer::time(|| sorter.sort_i64_with_scratch(&mut job.data, &params, scratch));
    let valid = match fp {
        Some(fp) => validate::validate_i64(fp, &job.data, threads) == Verdict::Valid,
        None => true,
    };
    metrics.incr("jobs.completed");
    metrics.observe("sort.latency", secs);
    metrics.add("elements.sorted", job.data.len() as u64);
    if !valid {
        metrics.incr("jobs.invalid");
    }
    SortOutcome { id, data: job.data, params, secs, valid }
}

/// Service configuration.
pub struct ServiceConfig {
    /// Concurrent sort jobs (each job internally uses `sort_threads`).
    pub workers: usize,
    /// Threads each sort uses.
    pub sort_threads: usize,
    /// Pending-job queue bound (backpressure).
    pub queue_capacity: usize,
    /// When set, the service owns an [`OnlineTuner`]: jobs feed fingerprint
    /// + latency observations to a background thread that refines cached
    /// parameters with incremental GA generations.
    pub autotune: Option<AutotunePolicy>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        let hw = crate::util::default_threads();
        ServiceConfig {
            workers: 2,
            sort_threads: hw.div_ceil(2),
            queue_capacity: 64,
            autotune: None,
        }
    }
}

/// A job's resolved parameters plus the observation the tuner wants back.
struct Resolution {
    params: SortParams,
    /// True when the parameters came from the tuning cache (false for
    /// overrides and symbolic fallbacks).
    cache_hit: bool,
    /// `(fingerprint label, retained pre-sort sample)` — `None` for
    /// explicit-override jobs or when autotuning is off.
    observe: Option<(String, Vec<i64>)>,
}

/// The coordinator service.
pub struct SortService {
    // Field order is drop order: the pool joins its workers (which hold
    // transient `Arc<OnlineTuner>` clones) before the tuner itself is
    // dropped and joined.
    pool: crate::exec::pool::ThreadPool,
    sorter: Arc<AdaptiveSorter>,
    cache: Arc<TuningCache>,
    model: SymbolicModel,
    metrics: Arc<Metrics>,
    tuner: Option<Arc<OnlineTuner>>,
    next_id: AtomicU64,
}

/// Resolve parameters for one job against shared service state: override →
/// fingerprint-keyed cache → symbolic model. The declared `job.dist` label
/// is NOT consulted — the cache key comes from the data itself, so
/// mislabeled jobs cannot poison the cache (they land in their own class).
///
/// A free function over the shared (`Arc`ed) state so the batched path can
/// run it *inside* worker shards: the fingerprint probe then parallelises
/// with the sorting instead of serialising on the submitting thread.
fn resolve_job(
    cache: &TuningCache,
    model: &SymbolicModel,
    metrics: &Metrics,
    tuner: Option<&OnlineTuner>,
    job: &SortJob,
) -> Resolution {
    if let Some(p) = job.params {
        metrics.incr("params.override");
        return Resolution { params: p, cache_hit: false, observe: None };
    }
    let label = Fingerprint::of(&job.data).label();
    let (params, cache_hit) = if let Some(p) = cache.get(job.data.len(), &label) {
        metrics.incr("params.cache_hit");
        (p, true)
    } else {
        metrics.incr("params.cache_miss");
        metrics.incr("params.symbolic");
        (model.params_for(job.data.len()), false)
    };
    // Retain a strided pre-sort sample for the tuner's GA fitness (the
    // post-sort data is sorted, which would bias tuning toward the
    // sorted-input special case). The copy is taken on only every k-th
    // job — the tuner keeps one sample per class, so paying the memcpy
    // for every job would be pure waste. An empty sample means "latency
    // observation only"; the tuner ignores it for fitness.
    let observe = tuner.map(|t| {
        let sample = if t.wants_sample(&label) {
            fingerprint::sample(&job.data, t.policy().retained_sample_cap)
        } else {
            Vec::new()
        };
        (label, sample)
    });
    Resolution { params, cache_hit, observe }
}

impl SortService {
    pub fn new(config: ServiceConfig) -> Self {
        Self::with_sorter(config, AdaptiveSorter::new(1))
    }

    /// Build with a prepared sorter (e.g. XLA backend attached). The sorter's
    /// thread budget is replaced by `config.sort_threads`.
    pub fn with_sorter(config: ServiceConfig, sorter: AdaptiveSorter) -> Self {
        let sorter = sorter.rebudget(config.sort_threads);
        let cache = Arc::new(TuningCache::new());
        let metrics = Arc::new(Metrics::new());
        let model = SymbolicModel::paper();
        let tuner = config.autotune.map(|policy| {
            Arc::new(OnlineTuner::spawn(
                policy,
                Arc::clone(&cache),
                Arc::clone(&metrics),
                model,
                config.sort_threads,
            ))
        });
        SortService {
            pool: crate::exec::pool::ThreadPool::with_capacity(
                config.workers,
                config.queue_capacity,
            ),
            sorter: Arc::new(sorter),
            cache,
            model,
            metrics,
            tuner,
            next_id: AtomicU64::new(1),
        }
    }

    /// Replace the symbolic model (e.g. one fitted on this machine).
    pub fn set_model(&mut self, model: SymbolicModel) {
        self.model = model;
    }

    pub fn cache(&self) -> &Arc<TuningCache> {
        &self.cache
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Whether a background tuner is attached.
    pub fn autotuning(&self) -> bool {
        self.tuner.is_some()
    }

    /// The fingerprint label `data` would resolve through — the tuning-cache
    /// key. Use this (not the declared distribution name) to pre-warm the
    /// cache: `svc.cache().put(data.len(), &SortService::fingerprint_label(&data), params)`.
    pub fn fingerprint_label(data: &[i64]) -> String {
        Fingerprint::of(data).label()
    }

    /// Submit a job; blocks only when the queue is full (backpressure).
    pub fn submit(&self, job: SortJob) -> JobHandle {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let sorter = Arc::clone(&self.sorter);
        let metrics = Arc::clone(&self.metrics);
        let Resolution { params, observe, .. } =
            resolve_job(&self.cache, &self.model, &self.metrics, self.tuner.as_deref(), &job);
        let tuner = self.tuner.clone();
        self.metrics.incr("jobs.submitted");
        let submitted = self.pool.submit(move || {
            let outcome = execute_job(&sorter, &metrics, id, job, params, &mut Vec::new());
            if let (Some(tuner), Some((label, sample))) = (&tuner, observe) {
                tuner.observe(Observation {
                    label,
                    n: outcome.data.len(),
                    secs: outcome.secs,
                    sample: Some(sample),
                });
            }
            let _ = tx.send(outcome);
        });
        assert!(submitted, "service is shutting down");
        JobHandle { id, rx }
    }

    /// Submit a whole batch of jobs in one call.
    ///
    /// The submit call itself only assigns ids and enqueues: parameter
    /// resolution (fingerprint probe + cache/model lookup) runs *inside*
    /// the worker shards, so probing parallelises with sorting and the
    /// caller returns immediately. Jobs flow through a shared work queue
    /// drained by up to `pool.threads()` pool tasks, so shards balance
    /// dynamically under mixed job sizes and every shard reuses a single
    /// radix scratch buffer across all the jobs it executes — the
    /// `sort_i64_with_scratch` hot path allocates nothing after the first
    /// large job. Per-job latencies stream into the `batch.job.latency`
    /// sample window; [`BatchHandle::wait`] publishes p50/p99/jobs-per-sec
    /// plus the batch's tuning-cache hit/miss counts.
    pub fn submit_batch(&self, jobs: Vec<SortJob>) -> BatchHandle {
        let started = Instant::now();
        let total = jobs.len();
        let (tx, rx) = mpsc::channel();
        // Keep the shared counters consistent with the single-job path
        // (jobs.submitted >= jobs.completed must hold across mixed traffic).
        self.metrics.add("jobs.submitted", total as u64);
        self.metrics.add("batch.jobs.submitted", total as u64);
        self.metrics.incr("batch.submitted");
        let cache_hits = Arc::new(AtomicU64::new(0));
        let cache_misses = Arc::new(AtomicU64::new(0));
        let queue: VecDeque<(usize, u64, SortJob)> = jobs
            .into_iter()
            .enumerate()
            .map(|(idx, job)| (idx, self.next_id.fetch_add(1, Ordering::Relaxed), job))
            .collect();
        let queue = Arc::new(Mutex::new(queue));
        let shards = self.pool.threads().min(total.max(1));
        for _ in 0..shards {
            let queue = Arc::clone(&queue);
            let sorter = Arc::clone(&self.sorter);
            let cache = Arc::clone(&self.cache);
            let model = self.model;
            let metrics = Arc::clone(&self.metrics);
            let tuner = self.tuner.clone();
            let hits = Arc::clone(&cache_hits);
            let misses = Arc::clone(&cache_misses);
            let tx = tx.clone();
            let submitted = self.pool.submit(move || {
                // Per-shard scratch, reused across every job this shard pulls.
                let mut scratch: Vec<i64> = Vec::new();
                loop {
                    let item = queue.lock().unwrap().pop_front();
                    let Some((idx, id, job)) = item else { break };
                    let Resolution { params, cache_hit, observe } =
                        resolve_job(&cache, &model, &metrics, tuner.as_deref(), &job);
                    if job.params.is_none() {
                        if cache_hit {
                            hits.fetch_add(1, Ordering::Relaxed);
                        } else {
                            misses.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    let outcome = execute_job(&sorter, &metrics, id, job, params, &mut scratch);
                    metrics.observe_sample("batch.job.latency", outcome.secs);
                    if let (Some(tuner), Some((label, sample))) = (&tuner, observe) {
                        tuner.observe(Observation {
                            label,
                            n: outcome.data.len(),
                            secs: outcome.secs,
                            sample: Some(sample),
                        });
                    }
                    let _ = tx.send((idx, outcome));
                }
            });
            assert!(submitted, "service is shutting down");
        }
        BatchHandle {
            total,
            started,
            rx,
            metrics: Arc::clone(&self.metrics),
            cache_hits,
            cache_misses,
        }
    }

    /// Block until every submitted job has completed.
    pub fn drain(&self) {
        self.pool.wait_idle();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_i64, Distribution};

    fn service() -> SortService {
        SortService::new(ServiceConfig {
            workers: 2,
            sort_threads: 2,
            queue_capacity: 8,
            autotune: None,
        })
    }

    #[test]
    fn submit_and_wait_sorted() {
        let svc = service();
        let data = generate_i64(150_000, Distribution::Uniform, 1, 2);
        let mut expect = data.clone();
        expect.sort_unstable();
        let out = svc.submit(SortJob::new(data)).wait();
        assert!(out.valid);
        assert_eq!(out.data, expect);
        assert!(out.secs > 0.0);
        assert_eq!(svc.metrics().counter("jobs.completed"), 1);
    }

    #[test]
    fn many_concurrent_jobs() {
        let svc = service();
        let handles: Vec<JobHandle> = (0..10u64)
            .map(|seed| {
                let data = generate_i64(30_000, Distribution::Uniform, seed, 2);
                svc.submit(SortJob::new(data))
            })
            .collect();
        let mut ids = std::collections::HashSet::new();
        for h in handles {
            let out = h.wait();
            assert!(out.valid);
            assert!(out.data.windows(2).all(|w| w[0] <= w[1]));
            ids.insert(out.id);
        }
        assert_eq!(ids.len(), 10, "unique job ids");
        assert_eq!(svc.metrics().counter("jobs.completed"), 10);
        assert_eq!(svc.metrics().counter("jobs.invalid"), 0);
    }

    #[test]
    fn params_resolution_order() {
        let svc = service();
        // 1. symbolic (cold cache).
        let out = svc.submit(SortJob::new(generate_i64(200_000, Distribution::Uniform, 3, 2))).wait();
        assert!(out.valid);
        assert_eq!(svc.metrics().counter("params.symbolic"), 1);
        assert_eq!(svc.metrics().counter("params.cache_miss"), 1);
        // 2. cache hit after put under the data's fingerprint label.
        let data = generate_i64(200_000, Distribution::Uniform, 4, 2);
        let label = SortService::fingerprint_label(&data);
        svc.cache().put(data.len(), &label, SortParams::paper_1e7());
        let out = svc.submit(SortJob::new(data)).wait();
        assert_eq!(out.params, SortParams::paper_1e7());
        assert_eq!(svc.metrics().counter("params.cache_hit"), 1);
        // 3. explicit override wins.
        let mut job = SortJob::new(generate_i64(200_000, Distribution::Uniform, 5, 2));
        let custom = SortParams { tile: 777, ..SortParams::paper_1e7() };
        job.params = Some(custom);
        let out = svc.submit(job).wait();
        assert_eq!(out.params.tile, 777);
        assert_eq!(svc.metrics().counter("params.override"), 1);
    }

    #[test]
    fn mislabeled_dist_cannot_poison_the_cache() {
        // Regression test for the PR-1 label-trust bug: the cache used to be
        // keyed on the caller-declared `dist` string, so parameters tuned
        // for one workload were served to *any* job claiming that label in
        // the same size band. Fingerprint keying puts mislabeled jobs in
        // their own class.
        let svc = service();
        let uniform = generate_i64(150_000, Distribution::Uniform, 7, 2);
        let sorted = generate_i64(150_000, Distribution::Sorted, 7, 2);
        let uniform_label = SortService::fingerprint_label(&uniform);
        let sorted_label = SortService::fingerprint_label(&sorted);
        assert_ne!(uniform_label, sorted_label, "shapes must land in different classes");

        // "Poison" the uniform class with pathological parameters.
        let poison = SortParams { tile: 64, insertion_threshold: 16, ..SortParams::paper_1e7() };
        svc.cache().put(uniform.len(), &uniform_label, poison);

        // A sorted-data job *claiming* to be uniform does not see them…
        let mut mislabeled = SortJob::new(sorted);
        mislabeled.dist = "uniform".to_string();
        let out = svc.submit(mislabeled).wait();
        assert!(out.valid);
        assert_ne!(out.params, poison, "mislabeled job must not resolve through the uniform class");
        assert_eq!(svc.metrics().counter("params.cache_hit"), 0);

        // …while genuinely uniform data still hits its class.
        let out = svc.submit(SortJob::new(uniform)).wait();
        assert_eq!(out.params, poison);
        assert_eq!(svc.metrics().counter("params.cache_hit"), 1);
    }

    #[test]
    fn drain_waits_for_all() {
        let svc = service();
        for seed in 0..5u64 {
            // Fire-and-forget: drop the handles.
            let _ = svc.submit(SortJob::new(generate_i64(20_000, Distribution::Uniform, seed, 2)));
        }
        svc.drain();
        assert_eq!(svc.metrics().counter("jobs.completed"), 5);
    }

    #[test]
    fn skip_validation_path() {
        let svc = service();
        let mut job = SortJob::new(generate_i64(50_000, Distribution::Uniform, 9, 2));
        job.validate = false;
        let out = svc.submit(job).wait();
        assert!(out.valid, "unvalidated jobs report valid=true");
        assert!(out.data.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn batch_sorts_everything_in_order() {
        let svc = service();
        let jobs: Vec<SortJob> = (0..24u64)
            .map(|seed| SortJob::new(generate_i64(5_000 + (seed as usize * 379) % 20_000, Distribution::Uniform, seed, 2)))
            .collect();
        let expected: Vec<Vec<i64>> = jobs
            .iter()
            .map(|j| {
                let mut v = j.data.clone();
                v.sort_unstable();
                v
            })
            .collect();
        let report = svc.submit_batch(jobs).wait();
        assert_eq!(report.outcomes.len(), 24);
        assert_eq!(report.stats.jobs, 24);
        assert_eq!(report.stats.invalid, 0);
        for (out, want) in report.outcomes.iter().zip(&expected) {
            assert!(out.valid);
            assert_eq!(&out.data, want, "batch outcomes must keep submission order");
        }
        // Unique ids across the batch.
        let ids: std::collections::HashSet<u64> = report.outcomes.iter().map(|o| o.id).collect();
        assert_eq!(ids.len(), 24);
        // Stats are consistent.
        assert!(report.stats.p50_secs <= report.stats.p99_secs);
        assert!(report.stats.jobs_per_sec > 0.0);
        assert_eq!(
            report.stats.elements,
            expected.iter().map(|v| v.len() as u64).sum::<u64>()
        );
        // Metrics published.
        assert_eq!(svc.metrics().counter("batch.jobs.submitted"), 24);
        assert_eq!(svc.metrics().counter("batch.completed"), 1);
        assert_eq!(svc.metrics().counter("jobs.completed"), 24);
        assert!(svc.metrics().gauge("batch.last.jobs_per_sec").unwrap() > 0.0);
        assert!(svc.metrics().percentile("batch.job.latency", 99.0).is_some());
    }

    #[test]
    fn batch_edge_cases_empty_and_tiny() {
        let svc = service();
        // Empty batch.
        let report = svc.submit_batch(Vec::new()).wait();
        assert!(report.outcomes.is_empty());
        assert_eq!(report.stats.jobs_per_sec, 0.0);
        assert_eq!(report.stats.p99_secs, 0.0);
        // Batch containing empty-slice and single-element jobs.
        let jobs = vec![
            SortJob::new(vec![]),
            SortJob::new(vec![7]),
            SortJob::new(vec![3, -1]),
        ];
        let report = svc.submit_batch(jobs).wait();
        assert_eq!(report.outcomes[0].data, Vec::<i64>::new());
        assert_eq!(report.outcomes[1].data, vec![7]);
        assert_eq!(report.outcomes[2].data, vec![-1, 3]);
        assert!(report.outcomes.iter().all(|o| o.valid));
    }

    #[test]
    fn batch_respects_param_override_and_cache() {
        let svc = service();
        let cached_data = generate_i64(120_000, Distribution::Uniform, 2, 2);
        svc.cache().put(
            cached_data.len(),
            &SortService::fingerprint_label(&cached_data),
            SortParams::paper_1e8(),
        );
        let mut override_job = SortJob::new(generate_i64(120_000, Distribution::Uniform, 1, 2));
        override_job.params = Some(SortParams { tile: 333, ..SortParams::paper_1e7() });
        let cached_job = SortJob::new(cached_data);
        let report = svc.submit_batch(vec![override_job, cached_job]).wait();
        assert_eq!(report.outcomes[0].params.tile, 333);
        assert_eq!(report.outcomes[1].params, SortParams::paper_1e8());
        assert_eq!(svc.metrics().counter("params.override"), 1);
        assert_eq!(svc.metrics().counter("params.cache_hit"), 1);
        // The batch report carries its own hit/miss accounting (overrides
        // count as neither).
        assert_eq!(report.stats.cache_hits, 1);
        assert_eq!(report.stats.cache_misses, 0);
    }
}
