//! The EvoSort master pipeline — Algorithm 1 of the paper — plus the batched
//! service workload driver.
//!
//! For each requested dataset size: run GA tuning, generate the data array,
//! compute the reference sort, run Adaptive Partition Sort with the tuned
//! parameters, assert the output matches the reference, and compare runtime
//! against the baselines (the paper's `np.sort` quicksort/mergesort).
//!
//! [`BatchWorkload`] models the service-traffic shape (many independent jobs
//! of mixed sizes and distributions) and drives it through
//! [`SortService::submit_batch_requests`](crate::coordinator::SortService::submit_batch_requests),
//! reporting jobs/sec and p50/p99 latency.

use crate::coordinator::request::SortRequest;
use crate::coordinator::service::{BatchReport, SortService};
use crate::data::{self, validate, Distribution};
use crate::ga::{GaConfig, GaDriver, GaResult};
use crate::params::SortParams;
use crate::sort::{AdaptiveSorter, Baseline, Dtype, SortPayload};
use crate::util::{fmt_count, fmt_secs, timer};

/// How the pipeline obtains parameters for the final sort.
#[derive(Debug, Clone)]
pub enum ParamSource {
    /// Run GA tuning per size (Algorithm 1 line 2).
    Ga(GaConfig),
    /// Use the symbolic model (§7 deployment path) — zero tuning overhead.
    Symbolic(crate::symbolic::SymbolicModel),
    /// Fixed parameters (ablations).
    Fixed(SortParams),
}

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub sizes: Vec<usize>,
    pub dist: Distribution,
    pub seed: u64,
    pub threads: usize,
    pub params: ParamSource,
    /// Cap on the GA's tuning-sample size (the paper tunes on the full array;
    /// a cap keeps wall-clock sane at bench scale).
    pub sample_cap: usize,
    /// Which baselines to time alongside (empty = skip comparison).
    pub baselines: Vec<Baseline>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            sizes: vec![1_000_000, 10_000_000],
            dist: Distribution::Uniform,
            seed: 42,
            threads: crate::util::default_threads(),
            params: ParamSource::Ga(GaConfig::default()),
            sample_cap: 4_000_000,
            baselines: vec![Baseline::Quicksort, Baseline::Mergesort],
        }
    }
}

/// Result row for one dataset size — one line of Table 1.
#[derive(Debug, Clone)]
pub struct PipelineRow {
    pub n: usize,
    pub params: SortParams,
    pub evosort_secs: f64,
    /// `(baseline, seconds, speedup)` triples.
    pub baselines: Vec<(Baseline, f64, f64)>,
    pub validated: bool,
    /// GA convergence history when GA tuning ran (Figures 2–6 data).
    pub ga: Option<GaResult>,
}

impl PipelineRow {
    /// Best (largest) speedup across baselines — the paper's headline factor.
    pub fn best_speedup(&self) -> f64 {
        self.baselines.iter().map(|(_, _, s)| *s).fold(0.0, f64::max)
    }

    pub fn table_line(&self) -> String {
        let bl = self
            .baselines
            .iter()
            .map(|(b, t, s)| format!("{}={} ({s:.1}x)", b.name(), fmt_secs(*t)))
            .collect::<Vec<_>>()
            .join("  ");
        format!(
            "{:>6}  evosort={}  {}  params={}  valid={}",
            fmt_count(self.n),
            fmt_secs(self.evosort_secs),
            bl,
            self.params,
            self.validated
        )
    }
}

/// Run Algorithm 1 over every size in the config.
pub fn run(config: &PipelineConfig) -> Vec<PipelineRow> {
    run_with_sorter(config, AdaptiveSorter::new(config.threads))
}

/// Variant accepting a prepared sorter (e.g. with the XLA backend attached).
pub fn run_with_sorter(config: &PipelineConfig, sorter: AdaptiveSorter) -> Vec<PipelineRow> {
    let mut rows = Vec::with_capacity(config.sizes.len());
    for &n in &config.sizes {
        crate::log_info!("pipeline: n={}", fmt_count(n));

        // (1) parameters.
        let (params, ga) = match &config.params {
            ParamSource::Ga(cfg) => {
                let driver = GaDriver::new(cfg.clone());
                let result = driver.run_for_size(
                    n,
                    config.sample_cap,
                    config.dist,
                    AdaptiveSorter::new(config.threads),
                );
                crate::log_info!(
                    "GA best for {}: {} ({}, {} evals)",
                    fmt_count(n),
                    result.best,
                    fmt_secs(result.best_fitness),
                    result.evaluations
                );
                (result.best, Some(result))
            }
            ParamSource::Symbolic(model) => (model.params_for(n), None),
            ParamSource::Fixed(p) => (*p, None),
        };

        // (2) data generation.
        let mut array = data::generate_i64(n, config.dist, config.seed, config.threads);
        let fp = validate::fingerprint_i64(&array, config.threads);

        // (4) final sort with tuned parameters (timed).
        let (_, evosort_secs) = timer::time(|| sorter.sort_i64(&mut array, &params));

        // (5) validation — ordering + multiset (replaces the paper's
        // element-by-element comparison with the reference array, without
        // needing a second n-sized buffer).
        let verdict = validate::validate_i64(fp, &array, config.threads);
        let validated = verdict == validate::Verdict::Valid;
        if !validated {
            crate::log_error!("validation FAILED for n={n}: {verdict:?}");
        }

        // Baseline comparison (fresh copies, same seed).
        let mut baselines = Vec::new();
        for &b in &config.baselines {
            let mut copy = data::generate_i64(n, config.dist, config.seed, config.threads);
            let (_, secs) = timer::time(|| b.sort_i64(&mut copy));
            debug_assert_eq!(copy, array);
            baselines.push((b, secs, secs / evosort_secs));
        }

        let row = PipelineRow { n, params, evosort_secs, baselines, validated, ga };
        crate::log_info!("{}", row.table_line());
        rows.push(row);
    }
    rows
}

/// A deterministic mixed workload for the batched service path: `jobs` jobs
/// whose sizes and distributions cycle through the given lists (coprime-ish
/// list lengths give good mixing), with per-job seeds derived from `seed`.
/// Data is generated i64-native and projected onto `dtype` with an
/// order-preserving map, so the same workload shape can exercise any key
/// dtype the service supports (`serve --dtype f64`).
#[derive(Debug, Clone)]
pub struct BatchWorkload {
    pub jobs: usize,
    pub sizes: Vec<usize>,
    pub dists: Vec<Distribution>,
    pub seed: u64,
    /// Validate each job's output inside the service (one extra pass).
    pub validate: bool,
    /// Key dtype every job is generated as.
    pub dtype: Dtype,
}

impl Default for BatchWorkload {
    fn default() -> Self {
        BatchWorkload {
            jobs: 1000,
            sizes: vec![1_000, 4_000, 16_000, 64_000, 0, 1, 250_000],
            dists: vec![
                Distribution::Uniform,
                Distribution::Zipf,
                Distribution::NearlySorted,
                Distribution::FewUnique,
            ],
            seed: 42,
            validate: true,
            dtype: Dtype::I64,
        }
    }
}

impl BatchWorkload {
    /// Materialise the request list (deterministic for a fixed config).
    pub fn generate(&self, threads: usize) -> Vec<SortRequest> {
        assert!(!self.sizes.is_empty() && !self.dists.is_empty(), "workload lists must be non-empty");
        (0..self.jobs)
            .map(|i| {
                let n = self.sizes[i % self.sizes.len()];
                let dist = self.dists[i % self.dists.len()];
                let seed = self.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                let data = data::generate_i64(n, dist, seed, threads);
                let payload = SortPayload::from_i64_values(data, self.dtype);
                let mut req = SortRequest::from_payload(payload).with_dist(dist.name());
                req.validate = self.validate;
                req
            })
            .collect()
    }

    /// Generate the workload and push it through the batched service path.
    /// Callers print [`batch_summary_line`] themselves; this only logs at
    /// debug level to avoid duplicating CLI output.
    pub fn run(&self, svc: &SortService, threads: usize) -> BatchReport {
        let requests = self.generate(threads);
        let report = svc.submit_batch_requests(requests).wait();
        crate::log_debug!("{}", batch_summary_line(&report));
        report
    }
}

/// One-line human-readable summary of a [`BatchReport`].
pub fn batch_summary_line(report: &BatchReport) -> String {
    let mut line = format!(
        "batch: {} jobs ({} elems) in {}  {:.1} jobs/s  p50={} p99={} invalid={} failed={} cache={}h/{}m",
        report.stats.jobs,
        fmt_count(report.stats.elements as usize),
        fmt_secs(report.wall_secs),
        report.stats.jobs_per_sec,
        fmt_secs(report.stats.p50_secs),
        fmt_secs(report.stats.p99_secs),
        report.stats.invalid,
        report.stats.failed,
        report.stats.cache_hits,
        report.stats.cache_misses
    );
    if report.stats.per_dtype.len() > 1 {
        let parts: Vec<String> =
            report.stats.per_dtype.iter().map(|d| format!("{}:{}", d.dtype, d.jobs)).collect();
        line.push_str(&format!("  dtypes=[{}]", parts.join(" ")));
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_fixed_params_runs_and_validates() {
        let config = PipelineConfig {
            sizes: vec![50_000, 120_000],
            threads: 2,
            params: ParamSource::Fixed(SortParams::paper_1e7()),
            baselines: vec![Baseline::Std],
            ..Default::default()
        };
        let rows = run(&config);
        assert_eq!(rows.len(), 2);
        for row in &rows {
            assert!(row.validated, "row {} invalid", row.n);
            assert!(row.evosort_secs > 0.0);
            assert_eq!(row.baselines.len(), 1);
            assert!(row.best_speedup() > 0.0);
            assert!(row.ga.is_none());
        }
    }

    #[test]
    fn pipeline_with_ga_records_history() {
        let config = PipelineConfig {
            sizes: vec![60_000],
            threads: 2,
            params: ParamSource::Ga(GaConfig { population: 6, generations: 2, seed: 5, ..Default::default() }),
            sample_cap: 30_000,
            baselines: vec![],
            ..Default::default()
        };
        let rows = run(&config);
        let ga = rows[0].ga.as_ref().expect("ga history");
        assert_eq!(ga.history.len(), 3); // gen 0..=2
        assert!(rows[0].validated);
    }

    #[test]
    fn pipeline_symbolic_params() {
        let config = PipelineConfig {
            sizes: vec![80_000],
            threads: 2,
            params: ParamSource::Symbolic(crate::symbolic::SymbolicModel::paper()),
            baselines: vec![],
            ..Default::default()
        };
        let rows = run(&config);
        assert!(rows[0].validated);
        assert_eq!(rows[0].params.algorithm, crate::params::ACode::Radix);
    }

    #[test]
    fn table_line_formats() {
        let row = PipelineRow {
            n: 10_000_000,
            params: SortParams::paper_1e7(),
            evosort_secs: 0.2886,
            baselines: vec![(Baseline::Quicksort, 0.8157, 2.83)],
            validated: true,
            ga: None,
        };
        let line = row.table_line();
        assert!(line.contains("1e7"), "{line}");
        assert!(line.contains("0.2886s"));
        assert!(line.contains("2.8x"));
    }

    #[test]
    fn batch_workload_generation_is_deterministic_and_mixed() {
        let wl = BatchWorkload {
            jobs: 12,
            sizes: vec![100, 0, 2_000],
            dists: vec![Distribution::Uniform, Distribution::Zipf],
            seed: 9,
            ..Default::default()
        };
        let a = wl.generate(2);
        let b = wl.generate(4);
        assert_eq!(a.len(), 12);
        for (ja, jb) in a.iter().zip(&b) {
            assert_eq!(ja.payload(), jb.payload(), "generation must be thread-count independent");
            assert_eq!(ja.dist, jb.dist);
        }
        // Sizes cycle 100, 0, 2000, ...
        assert_eq!(a[0].len(), 100);
        assert_eq!(a[1].len(), 0);
        assert_eq!(a[2].len(), 2_000);
        assert_eq!(a[3].len(), 100);
        // Distributions cycle uniform, zipf, ...
        assert_eq!(a[0].dist, "uniform");
        assert_eq!(a[1].dist, "zipf");
        // Different seeds give different data.
        let c = BatchWorkload { seed: 10, ..wl }.generate(2);
        assert_ne!(a[0].payload(), c[0].payload());
    }

    #[test]
    fn batch_workload_typed_dtypes_round_trip() {
        for &dtype in crate::sort::Dtype::all() {
            let wl = BatchWorkload {
                jobs: 8,
                sizes: vec![0, 1, 3_000],
                dists: vec![Distribution::Uniform, Distribution::FewUnique],
                seed: 5,
                dtype,
                ..Default::default()
            };
            let reqs = wl.generate(2);
            assert!(reqs.iter().all(|r| r.dtype() == dtype), "{dtype}");
            let svc = SortService::new(crate::coordinator::ServiceConfig::sized(2, 2, 8));
            let report = svc.submit_batch_requests(reqs).wait();
            assert_eq!(report.stats.jobs, 8, "{dtype}");
            assert_eq!(report.stats.invalid, 0, "{dtype}");
            assert_eq!(report.stats.failed, 0, "{dtype}");
            assert_eq!(report.stats.per_dtype.len(), 1);
            assert_eq!(report.stats.per_dtype[0].dtype, dtype);
        }
    }

    #[test]
    fn batch_workload_runs_through_service() {
        let wl = BatchWorkload {
            jobs: 40,
            sizes: vec![1_000, 0, 1, 8_000],
            dists: vec![Distribution::Uniform, Distribution::FewUnique],
            seed: 3,
            ..Default::default()
        };
        let svc = SortService::new(crate::coordinator::ServiceConfig::sized(2, 2, 8));
        let report = wl.run(&svc, 2);
        assert_eq!(report.stats.jobs, 40);
        assert_eq!(report.stats.invalid, 0);
        for out in report.outputs() {
            let data = out.data::<i64>().expect("i64 workload");
            assert!(data.windows(2).all(|w| w[0] <= w[1]));
        }
        let line = batch_summary_line(&report);
        assert!(line.contains("40 jobs"), "{line}");
        assert!(line.contains("failed=0"), "{line}");
    }
}
