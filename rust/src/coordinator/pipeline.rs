//! The EvoSort master pipeline — Algorithm 1 of the paper.
//!
//! For each requested dataset size: run GA tuning, generate the data array,
//! compute the reference sort, run Adaptive Partition Sort with the tuned
//! parameters, assert the output matches the reference, and compare runtime
//! against the baselines (the paper's `np.sort` quicksort/mergesort).

use crate::data::{self, validate, Distribution};
use crate::ga::{GaConfig, GaDriver, GaResult};
use crate::params::SortParams;
use crate::sort::{AdaptiveSorter, Baseline};
use crate::util::{fmt_count, fmt_secs, timer};

/// How the pipeline obtains parameters for the final sort.
#[derive(Debug, Clone)]
pub enum ParamSource {
    /// Run GA tuning per size (Algorithm 1 line 2).
    Ga(GaConfig),
    /// Use the symbolic model (§7 deployment path) — zero tuning overhead.
    Symbolic(crate::symbolic::SymbolicModel),
    /// Fixed parameters (ablations).
    Fixed(SortParams),
}

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub sizes: Vec<usize>,
    pub dist: Distribution,
    pub seed: u64,
    pub threads: usize,
    pub params: ParamSource,
    /// Cap on the GA's tuning-sample size (the paper tunes on the full array;
    /// a cap keeps wall-clock sane at bench scale).
    pub sample_cap: usize,
    /// Which baselines to time alongside (empty = skip comparison).
    pub baselines: Vec<Baseline>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            sizes: vec![1_000_000, 10_000_000],
            dist: Distribution::Uniform,
            seed: 42,
            threads: crate::util::default_threads(),
            params: ParamSource::Ga(GaConfig::default()),
            sample_cap: 4_000_000,
            baselines: vec![Baseline::Quicksort, Baseline::Mergesort],
        }
    }
}

/// Result row for one dataset size — one line of Table 1.
#[derive(Debug, Clone)]
pub struct PipelineRow {
    pub n: usize,
    pub params: SortParams,
    pub evosort_secs: f64,
    /// `(baseline, seconds, speedup)` triples.
    pub baselines: Vec<(Baseline, f64, f64)>,
    pub validated: bool,
    /// GA convergence history when GA tuning ran (Figures 2–6 data).
    pub ga: Option<GaResult>,
}

impl PipelineRow {
    /// Best (largest) speedup across baselines — the paper's headline factor.
    pub fn best_speedup(&self) -> f64 {
        self.baselines.iter().map(|(_, _, s)| *s).fold(0.0, f64::max)
    }

    pub fn table_line(&self) -> String {
        let bl = self
            .baselines
            .iter()
            .map(|(b, t, s)| format!("{}={} ({s:.1}x)", b.name(), fmt_secs(*t)))
            .collect::<Vec<_>>()
            .join("  ");
        format!(
            "{:>6}  evosort={}  {}  params={}  valid={}",
            fmt_count(self.n),
            fmt_secs(self.evosort_secs),
            bl,
            self.params,
            self.validated
        )
    }
}

/// Run Algorithm 1 over every size in the config.
pub fn run(config: &PipelineConfig) -> Vec<PipelineRow> {
    run_with_sorter(config, AdaptiveSorter::new(config.threads))
}

/// Variant accepting a prepared sorter (e.g. with the XLA backend attached).
pub fn run_with_sorter(config: &PipelineConfig, sorter: AdaptiveSorter) -> Vec<PipelineRow> {
    let mut rows = Vec::with_capacity(config.sizes.len());
    for &n in &config.sizes {
        crate::log_info!("pipeline: n={}", fmt_count(n));

        // (1) parameters.
        let (params, ga) = match &config.params {
            ParamSource::Ga(cfg) => {
                let driver = GaDriver::new(cfg.clone());
                let result = driver.run_for_size(
                    n,
                    config.sample_cap,
                    config.dist,
                    AdaptiveSorter::new(config.threads),
                );
                crate::log_info!(
                    "GA best for {}: {} ({}, {} evals)",
                    fmt_count(n),
                    result.best,
                    fmt_secs(result.best_fitness),
                    result.evaluations
                );
                (result.best, Some(result))
            }
            ParamSource::Symbolic(model) => (model.params_for(n), None),
            ParamSource::Fixed(p) => (*p, None),
        };

        // (2) data generation.
        let mut array = data::generate_i64(n, config.dist, config.seed, config.threads);
        let fp = validate::fingerprint_i64(&array, config.threads);

        // (4) final sort with tuned parameters (timed).
        let (_, evosort_secs) = timer::time(|| sorter.sort_i64(&mut array, &params));

        // (5) validation — ordering + multiset (replaces the paper's
        // element-by-element comparison with the reference array, without
        // needing a second n-sized buffer).
        let verdict = validate::validate_i64(fp, &array, config.threads);
        let validated = verdict == validate::Verdict::Valid;
        if !validated {
            crate::log_error!("validation FAILED for n={n}: {verdict:?}");
        }

        // Baseline comparison (fresh copies, same seed).
        let mut baselines = Vec::new();
        for &b in &config.baselines {
            let mut copy = data::generate_i64(n, config.dist, config.seed, config.threads);
            let (_, secs) = timer::time(|| b.sort_i64(&mut copy));
            debug_assert_eq!(copy, array);
            baselines.push((b, secs, secs / evosort_secs));
        }

        let row = PipelineRow { n, params, evosort_secs, baselines, validated, ga };
        crate::log_info!("{}", row.table_line());
        rows.push(row);
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_fixed_params_runs_and_validates() {
        let config = PipelineConfig {
            sizes: vec![50_000, 120_000],
            threads: 2,
            params: ParamSource::Fixed(SortParams::paper_1e7()),
            baselines: vec![Baseline::Std],
            ..Default::default()
        };
        let rows = run(&config);
        assert_eq!(rows.len(), 2);
        for row in &rows {
            assert!(row.validated, "row {} invalid", row.n);
            assert!(row.evosort_secs > 0.0);
            assert_eq!(row.baselines.len(), 1);
            assert!(row.best_speedup() > 0.0);
            assert!(row.ga.is_none());
        }
    }

    #[test]
    fn pipeline_with_ga_records_history() {
        let config = PipelineConfig {
            sizes: vec![60_000],
            threads: 2,
            params: ParamSource::Ga(GaConfig { population: 6, generations: 2, seed: 5, ..Default::default() }),
            sample_cap: 30_000,
            baselines: vec![],
            ..Default::default()
        };
        let rows = run(&config);
        let ga = rows[0].ga.as_ref().expect("ga history");
        assert_eq!(ga.history.len(), 3); // gen 0..=2
        assert!(rows[0].validated);
    }

    #[test]
    fn pipeline_symbolic_params() {
        let config = PipelineConfig {
            sizes: vec![80_000],
            threads: 2,
            params: ParamSource::Symbolic(crate::symbolic::SymbolicModel::paper()),
            baselines: vec![],
            ..Default::default()
        };
        let rows = run(&config);
        assert!(rows[0].validated);
        assert_eq!(rows[0].params.algorithm, crate::params::ACode::Radix);
    }

    #[test]
    fn table_line_formats() {
        let row = PipelineRow {
            n: 10_000_000,
            params: SortParams::paper_1e7(),
            evosort_secs: 0.2886,
            baselines: vec![(Baseline::Quicksort, 0.8157, 2.83)],
            validated: true,
            ga: None,
        };
        let line = row.table_line();
        assert!(line.contains("1e7"), "{line}");
        assert!(line.contains("0.2886s"));
        assert!(line.contains("2.8x"));
    }
}
