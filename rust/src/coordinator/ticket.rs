//! Non-blocking job tickets: the async half of the typed service API.
//!
//! A [`Ticket`] is the service's IOU for one submitted
//! [`SortRequest`](crate::coordinator::SortRequest): the caller can poll it
//! ([`Ticket::try_result`]), park on it with a bound
//! ([`Ticket::wait_timeout`]), block ([`Ticket::wait`]), or abandon the job
//! ([`Ticket::cancel`]). All waiting is condvar-parked — no polling loops,
//! no spun cores.
//!
//! Delivery is a single mutex+condvar slot shared between the ticket and the
//! executing worker. The worker side holds a [`CompletionGuard`]: if the job
//! closure is dropped without completing — worker panic mid-sort, or a pool
//! that shut down before the job ran — the guard's `Drop` resolves the slot
//! with [`JobError::WorkerLost`], so a `wait` can never hang on a dead
//! worker and never panics on a disconnected channel (the failure mode of
//! the old `JobHandle`).

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::params::SortParams;
use crate::sort::{Dtype, SortKey, SortPayload};

/// A completed job: the sorted payload plus execution metadata.
#[derive(Debug)]
pub struct SortOutput {
    pub id: u64,
    /// The sorted data, still carrying its dtype.
    pub payload: SortPayload,
    /// Parameters the job resolved to (override → cache → symbolic model).
    pub params: SortParams,
    /// Sort wall time in seconds (excludes queueing).
    pub secs: f64,
    /// Output passed validation (always `true` when validation was skipped).
    pub valid: bool,
}

impl SortOutput {
    pub fn dtype(&self) -> Dtype {
        self.payload.dtype()
    }

    pub fn len(&self) -> usize {
        self.payload.len()
    }

    pub fn is_empty(&self) -> bool {
        self.payload.is_empty()
    }

    /// Borrow the sorted data as a typed slice (`None` on dtype mismatch).
    pub fn data<K: SortKey>(&self) -> Option<&[K]> {
        self.payload.as_slice::<K>()
    }

    /// Take the sorted data as a typed vector (`None` on dtype mismatch —
    /// the payload is dropped in that case; use [`SortOutput::payload`]
    /// directly to keep it).
    pub fn into_data<K: SortKey>(self) -> Option<Vec<K>> {
        self.payload.into_vec::<K>().ok()
    }
}

/// Why a job produced no [`SortOutput`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobError {
    /// [`Ticket::cancel`] won the race: the job was dequeued already
    /// cancelled and was never sorted (its payload is dropped).
    Cancelled,
    /// The executing worker died (panicking job) or the service shut down
    /// before the job could run.
    WorkerLost,
    /// Bounded admission shed the job: the router's queue was saturated at
    /// submission, so it resolved immediately instead of queueing
    /// unboundedly. Retry later or against another router.
    Overloaded,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::Cancelled => f.write_str("job cancelled before execution"),
            JobError::WorkerLost => f.write_str("worker lost before the job completed"),
            JobError::Overloaded => {
                f.write_str("router queue saturated; job shed at admission")
            }
        }
    }
}

impl std::error::Error for JobError {}

/// What a finished job resolves to.
pub type JobResult = Result<SortOutput, JobError>;

enum SlotState {
    /// Queued, not yet picked up by a worker.
    Pending,
    /// `cancel` was requested while still queued; the worker resolves to
    /// `Err(Cancelled)` at dequeue without sorting.
    CancelRequested,
    /// A worker has started executing — too late to cancel.
    Running,
    Done(JobResult),
    /// Result extracted by the ticket (terminal).
    Taken,
}

/// The shared single-job delivery slot.
pub(crate) struct JobSlot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

impl JobSlot {
    /// A fresh shared slot in the `Pending` state.
    pub(crate) fn pending() -> Arc<JobSlot> {
        Arc::new(JobSlot { state: Mutex::new(SlotState::Pending), cv: Condvar::new() })
    }

    /// Resolve the job. First resolution wins; later calls (e.g. the guard's
    /// `Drop` after an explicit completion raced with nothing — defensive)
    /// are ignored.
    pub(crate) fn complete(&self, result: JobResult) {
        self.complete_with(result, |_| {});
    }

    /// [`complete`](JobSlot::complete), invoking `observe` on the result
    /// **only when this resolution wins** the slot — the exactly-once seam
    /// terminal trace events hang off. `observe` runs under the slot lock;
    /// observers must be cheap and non-blocking (the tracer's ring push is).
    pub(crate) fn complete_with<F: FnOnce(&JobResult)>(&self, result: JobResult, observe: F) {
        let mut state = self.state.lock().unwrap();
        if matches!(
            *state,
            SlotState::Pending | SlotState::CancelRequested | SlotState::Running
        ) {
            observe(&result);
            *state = SlotState::Done(result);
            self.cv.notify_all();
        }
    }

    /// Worker-side transition at dequeue time: marks the job `Running` so a
    /// later `cancel` is refused, and reports whether a cancel had already
    /// landed (in which case the worker must not sort).
    pub(crate) fn start(&self) -> bool {
        let mut state = self.state.lock().unwrap();
        match *state {
            SlotState::CancelRequested => true,
            SlotState::Pending => {
                *state = SlotState::Running;
                false
            }
            _ => false,
        }
    }

    fn request_cancel(&self) -> bool {
        let mut state = self.state.lock().unwrap();
        match *state {
            SlotState::Pending => {
                *state = SlotState::CancelRequested;
                true
            }
            SlotState::CancelRequested => true,
            _ => false,
        }
    }

    fn is_finished(&self) -> bool {
        matches!(*self.state.lock().unwrap(), SlotState::Done(_) | SlotState::Taken)
    }

    fn try_take(&self) -> Option<JobResult> {
        let mut state = self.state.lock().unwrap();
        if matches!(*state, SlotState::Done(_)) {
            match std::mem::replace(&mut *state, SlotState::Taken) {
                SlotState::Done(r) => Some(r),
                _ => unreachable!("checked Done above"),
            }
        } else {
            None
        }
    }

    fn wait_take(&self) -> JobResult {
        let mut state = self.state.lock().unwrap();
        loop {
            if matches!(*state, SlotState::Done(_)) {
                match std::mem::replace(&mut *state, SlotState::Taken) {
                    SlotState::Done(r) => return r,
                    _ => unreachable!("checked Done above"),
                }
            }
            if matches!(*state, SlotState::Taken) {
                // Unreachable through the public API (taking consumes the
                // ticket) — resolve rather than hang if it ever happens.
                return Err(JobError::WorkerLost);
            }
            state = self.cv.wait(state).unwrap();
        }
    }

    fn wait_timeout_take(&self, timeout: Duration) -> Option<JobResult> {
        let deadline = std::time::Instant::now() + timeout;
        let mut state = self.state.lock().unwrap();
        loop {
            if matches!(*state, SlotState::Done(_)) {
                match std::mem::replace(&mut *state, SlotState::Taken) {
                    SlotState::Done(r) => return Some(r),
                    _ => unreachable!("checked Done above"),
                }
            }
            if matches!(*state, SlotState::Taken) {
                return Some(Err(JobError::WorkerLost));
            }
            let remaining = deadline.checked_duration_since(std::time::Instant::now())?;
            let (next, timed_out) = self.cv.wait_timeout(state, remaining).unwrap();
            state = next;
            if timed_out.timed_out() && !matches!(*state, SlotState::Done(_)) {
                return None;
            }
        }
    }
}

/// Worker-side completion obligation: resolves the slot with
/// [`JobError::WorkerLost`] if dropped before an explicit
/// [`complete`](CompletionGuard::complete) — including a drop *during panic
/// unwind* or a drop of a never-run closure on a shut-down pool.
/// Observer fired exactly once with the job's winning terminal result —
/// every path through a [`CompletionGuard`] (explicit completion, panic
/// unwind, dropped-unrun closure) funnels through it, which is what makes
/// "exactly one terminal trace event per job" an invariant rather than a
/// convention.
pub(crate) type TerminalObserver = Box<dyn FnOnce(&JobResult) + Send>;

pub(crate) struct CompletionGuard {
    slot: Arc<JobSlot>,
    done: bool,
    observer: Option<TerminalObserver>,
}

impl CompletionGuard {
    pub(crate) fn new(slot: Arc<JobSlot>) -> CompletionGuard {
        CompletionGuard { slot, done: false, observer: None }
    }

    /// Attach the terminal observer (builder style).
    pub(crate) fn with_observer(mut self, observer: TerminalObserver) -> CompletionGuard {
        self.observer = Some(observer);
        self
    }

    /// See [`JobSlot::start`]: call at dequeue; `true` means the job was
    /// cancelled and must not run (the guard should complete `Cancelled`).
    pub(crate) fn start(&self) -> bool {
        self.slot.start()
    }

    pub(crate) fn complete(mut self, result: JobResult) {
        match self.observer.take() {
            Some(obs) => self.slot.complete_with(result, obs),
            None => self.slot.complete(result),
        }
        self.done = true;
    }
}

impl Drop for CompletionGuard {
    fn drop(&mut self) {
        if !self.done {
            match self.observer.take() {
                Some(obs) => self.slot.complete_with(Err(JobError::WorkerLost), obs),
                None => self.slot.complete(Err(JobError::WorkerLost)),
            }
        }
    }
}

/// Handle to one in-flight job. Obtained from
/// [`SortService::submit_request`](crate::coordinator::SortService::submit_request).
///
/// A result can be extracted exactly once, enforced by move semantics: the
/// non-blocking accessors hand the ticket back when the job is still
/// pending.
///
/// ```
/// use evosort::coordinator::{ServiceConfig, SortRequest, SortService};
///
/// let svc = SortService::new(ServiceConfig::default());
/// let mut ticket = svc.submit_request(SortRequest::new(vec![3.5f64, -1.0, 2.25]));
/// // Poll without blocking…
/// let output = loop {
///     match ticket.try_result() {
///         Ok(result) => break result.expect("job failed"),
///         Err(pending) => ticket = pending, // not done yet — keep the ticket
///     }
/// };
/// assert_eq!(output.data::<f64>().unwrap(), &[-1.0, 2.25, 3.5]);
/// ```
#[must_use = "a Ticket is the only way to retrieve the job's result — drop it only to fire-and-forget"]
pub struct Ticket {
    id: u64,
    slot: Arc<JobSlot>,
}

impl Ticket {
    pub(crate) fn new(id: u64, slot: Arc<JobSlot>) -> Ticket {
        Ticket { id, slot }
    }

    /// The job id (matches [`SortOutput::id`]).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Has the job resolved (completed, failed, or cancelled)?
    pub fn is_finished(&self) -> bool {
        self.slot.is_finished()
    }

    /// Non-blocking poll: the result if the job has resolved, the ticket
    /// itself otherwise.
    pub fn try_result(self) -> Result<JobResult, Ticket> {
        match self.slot.try_take() {
            Some(r) => Ok(r),
            None => Err(self),
        }
    }

    /// Park (condvar, zero CPU) until the job resolves. Never hangs on a
    /// dead worker: a job lost to a panic or shutdown resolves to
    /// [`JobError::WorkerLost`].
    pub fn wait(self) -> JobResult {
        self.slot.wait_take()
    }

    /// Park for at most `timeout`. `Ok` with the result if the job resolved
    /// in time, `Err` with the ticket on timeout.
    pub fn wait_timeout(self, timeout: Duration) -> Result<JobResult, Ticket> {
        match self.slot.wait_timeout_take(timeout) {
            Some(r) => Ok(r),
            None => Err(self),
        }
    }

    /// Request cancellation. Returns `true` only when the request landed
    /// while the job was still **queued** (no worker had started it): the
    /// job is then guaranteed to resolve to [`JobError::Cancelled`] without
    /// sorting. Returns `false` when a worker already started — or finished
    /// — the job; its result stays retrievable as normal.
    pub fn cancel(&self) -> bool {
        self.slot.request_cancel()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn output(id: u64) -> SortOutput {
        SortOutput {
            id,
            payload: SortPayload::I64(vec![1, 2, 3]),
            params: SortParams::default(),
            secs: 0.001,
            valid: true,
        }
    }

    #[test]
    fn try_result_polls_then_takes() {
        let slot = JobSlot::pending();
        let ticket = Ticket::new(7, Arc::clone(&slot));
        assert!(!ticket.is_finished());
        let ticket = ticket.try_result().expect_err("pending: ticket comes back");
        slot.complete(Ok(output(7)));
        assert!(ticket.is_finished());
        let out = ticket.try_result().expect("done").expect("ok");
        assert_eq!(out.id, 7);
        assert_eq!(out.data::<i64>().unwrap(), &[1, 2, 3]);
    }

    #[test]
    fn wait_parks_until_completion() {
        let slot = JobSlot::pending();
        let ticket = Ticket::new(1, Arc::clone(&slot));
        let completer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            slot.complete(Ok(output(1)));
        });
        let out = ticket.wait().expect("ok");
        assert_eq!(out.id, 1);
        completer.join().unwrap();
    }

    #[test]
    fn wait_timeout_returns_ticket_then_result() {
        let slot = JobSlot::pending();
        let ticket = Ticket::new(2, Arc::clone(&slot));
        let ticket = ticket
            .wait_timeout(Duration::from_millis(20))
            .expect_err("pending job must time out");
        slot.complete(Err(JobError::WorkerLost));
        let res = ticket.wait_timeout(Duration::from_secs(5)).expect("resolved");
        assert_eq!(res.unwrap_err(), JobError::WorkerLost);
    }

    #[test]
    fn guard_drop_resolves_worker_lost() {
        let slot = JobSlot::pending();
        let ticket = Ticket::new(3, Arc::clone(&slot));
        drop(CompletionGuard::new(slot));
        assert_eq!(ticket.wait().unwrap_err(), JobError::WorkerLost);
    }

    #[test]
    fn guard_drop_during_panic_unwind_resolves() {
        let slot = JobSlot::pending();
        let ticket = Ticket::new(4, Arc::clone(&slot));
        let guard = CompletionGuard::new(slot);
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let _guard = guard;
            panic!("worker died mid-job");
        }));
        assert!(panicked.is_err());
        assert_eq!(ticket.wait().unwrap_err(), JobError::WorkerLost);
    }

    #[test]
    fn cancel_before_execution_wins() {
        let slot = JobSlot::pending();
        let ticket = Ticket::new(5, Arc::clone(&slot));
        assert!(ticket.cancel());
        assert!(ticket.cancel(), "idempotent while pending");
        // Worker dequeues, sees the request, resolves without sorting.
        let guard = CompletionGuard::new(Arc::clone(&slot));
        assert!(guard.start(), "start() reports the pending cancel");
        guard.complete(Err(JobError::Cancelled));
        assert_eq!(ticket.wait().unwrap_err(), JobError::Cancelled);
    }

    #[test]
    fn cancel_after_start_is_refused() {
        // Once a worker marked the job Running, cancel() must return false
        // and the job completes normally — `cancel() == true` is a hard
        // guarantee of Err(Cancelled).
        let slot = JobSlot::pending();
        let ticket = Ticket::new(9, Arc::clone(&slot));
        let guard = CompletionGuard::new(Arc::clone(&slot));
        assert!(!guard.start(), "no cancel pending: job starts");
        assert!(!ticket.cancel(), "running jobs cannot be cancelled");
        guard.complete(Ok(output(9)));
        assert!(ticket.wait().is_ok());
    }

    #[test]
    fn cancel_after_completion_is_refused() {
        let slot = JobSlot::pending();
        let ticket = Ticket::new(6, Arc::clone(&slot));
        slot.complete(Ok(output(6)));
        assert!(!ticket.cancel(), "completed jobs cannot be cancelled");
        assert!(ticket.wait().is_ok(), "result stays retrievable");
    }

    #[test]
    fn terminal_observer_fires_exactly_once_per_path() {
        use std::sync::atomic::{AtomicU32, Ordering};
        // Explicit completion path.
        let fired = Arc::new(AtomicU32::new(0));
        let slot = JobSlot::pending();
        let ticket = Ticket::new(10, Arc::clone(&slot));
        let f = Arc::clone(&fired);
        let guard = CompletionGuard::new(slot).with_observer(Box::new(move |r| {
            assert!(r.is_ok());
            f.fetch_add(1, Ordering::SeqCst);
        }));
        guard.complete(Ok(output(10)));
        assert_eq!(fired.load(Ordering::SeqCst), 1);
        assert!(ticket.wait().is_ok());

        // Drop path (worker lost) fires with the WorkerLost result.
        let fired = Arc::new(AtomicU32::new(0));
        let slot = JobSlot::pending();
        let ticket = Ticket::new(11, Arc::clone(&slot));
        let f = Arc::clone(&fired);
        drop(CompletionGuard::new(slot).with_observer(Box::new(move |r| {
            assert_eq!(*r, Err(JobError::WorkerLost));
            f.fetch_add(1, Ordering::SeqCst);
        })));
        assert_eq!(fired.load(Ordering::SeqCst), 1);
        assert_eq!(ticket.wait().unwrap_err(), JobError::WorkerLost);
    }

    #[test]
    fn terminal_observer_skipped_when_resolution_lost() {
        use std::sync::atomic::{AtomicU32, Ordering};
        // Someone else resolved the slot first: the guard's observer must
        // NOT fire — its resolution did not win, so no second terminal
        // event may be recorded.
        let fired = Arc::new(AtomicU32::new(0));
        let slot = JobSlot::pending();
        let ticket = Ticket::new(12, Arc::clone(&slot));
        slot.complete(Err(JobError::Overloaded));
        let f = Arc::clone(&fired);
        drop(CompletionGuard::new(slot).with_observer(Box::new(move |_| {
            f.fetch_add(1, Ordering::SeqCst);
        })));
        assert_eq!(fired.load(Ordering::SeqCst), 0);
        assert_eq!(ticket.wait().unwrap_err(), JobError::Overloaded);
    }

    #[test]
    fn explicit_complete_beats_guard_drop() {
        let slot = JobSlot::pending();
        let ticket = Ticket::new(8, Arc::clone(&slot));
        let guard = CompletionGuard::new(slot);
        guard.complete(Ok(output(8)));
        assert!(ticket.wait().is_ok());
    }
}
