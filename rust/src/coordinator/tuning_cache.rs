//! Tuning cache: remembers GA results per workload class so repeat sorts pay
//! zero tuning overhead (the gap §7 of the paper addresses with symbolic
//! models; the cache is the service-side complement).
//!
//! Keys are `(size_band, class)` — the size band is the integer part of
//! log10(n) · 2 (half-decade bands), since tuned thresholds vary smoothly in
//! log10 n (paper §7). The class string is a workload **fingerprint** label
//! ([`Fingerprint::label`](crate::autotune::Fingerprint::label)) computed
//! from the job's actual data — *not* the caller-declared distribution name,
//! which the service previously trusted and which let one mislabeled job
//! poison the cache for its whole size band.
//!
//! Persistence is a versioned plain text file (no serde crate offline): a
//! `# evosort-tuning-cache v2` header followed by `band class genes...`
//! lines. Loading is forgiving: corrupt, truncated, or out-of-bounds lines
//! are skipped with a warning, never propagated as `Err` or bad genes.

use std::collections::HashMap;
use std::path::Path;
use std::sync::RwLock;

use anyhow::{Context, Result};

use crate::params::{Bounds, SortParams};

/// Current on-disk format version (see [`TuningCache::save`]).
pub const FORMAT_VERSION: u32 = 2;

const HEADER_PREFIX: &str = "# evosort-tuning-cache v";

/// Workload class key.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub size_band: u32,
    pub dist: String,
}

impl CacheKey {
    /// Half-decade size banding: n ∈ [10^(b/2), 10^((b+1)/2)).
    pub fn band_of(n: usize) -> u32 {
        ((n.max(1) as f64).log10() * 2.0).floor() as u32
    }

    pub fn new(n: usize, dist: &str) -> CacheKey {
        CacheKey { size_band: Self::band_of(n), dist: dist.to_string() }
    }
}

/// Thread-safe tuned-parameter cache with text persistence.
#[derive(Default)]
pub struct TuningCache {
    map: RwLock<HashMap<CacheKey, SortParams>>,
}

impl TuningCache {
    pub fn new() -> Self {
        TuningCache::default()
    }

    pub fn get(&self, n: usize, dist: &str) -> Option<SortParams> {
        self.map.read().unwrap().get(&CacheKey::new(n, dist)).copied()
    }

    pub fn put(&self, n: usize, dist: &str, params: SortParams) {
        self.map.write().unwrap().insert(CacheKey::new(n, dist), params);
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of every entry (for reports and tests).
    pub fn entries(&self) -> Vec<(CacheKey, SortParams)> {
        self.map.read().unwrap().iter().map(|(k, p)| (k.clone(), *p)).collect()
    }

    /// Copy every entry of `other` into this cache (used to restore
    /// persisted parameters into a live service's shared cache). Returns the
    /// number of entries absorbed.
    pub fn absorb(&self, other: &TuningCache) -> usize {
        let theirs = other.map.read().unwrap();
        let mut ours = self.map.write().unwrap();
        for (k, p) in theirs.iter() {
            ours.insert(k.clone(), *p);
        }
        theirs.len()
    }

    /// Persist as a versioned header plus `band class g0 g1 g2 g3 g4` lines.
    pub fn save(&self, path: &Path) -> Result<()> {
        let map = self.map.read().unwrap();
        let mut lines: Vec<String> = map
            .iter()
            .map(|(k, p)| {
                let g = p.to_genes();
                format!(
                    "{} {} {} {} {} {} {}",
                    k.size_band, k.dist, g[0], g[1], g[2], g[3], g[4]
                )
            })
            .collect();
        lines.sort();
        let body = format!("{HEADER_PREFIX}{FORMAT_VERSION}\n{}\n", lines.join("\n"));
        std::fs::write(path, body).with_context(|| format!("writing {}", path.display()))
    }

    /// Load from the text format (headered v2 or legacy headerless v1).
    /// Corrupt, truncated, or out-of-bounds lines are skipped with a warning
    /// rather than failing the whole cache or clamping garbage genes into
    /// plausible-looking parameters.
    pub fn load(path: &Path) -> Result<TuningCache> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let cache = TuningCache::new();
        // The widest bounds any writer could have used: a persisted genome
        // outside them is corruption, not tuning.
        let bounds = Bounds::with_all_strategies();
        let mut legacy_keys = 0usize;
        {
            let mut map = cache.map.write().unwrap();
            for line in text.lines() {
                if let Some(rest) = line.strip_prefix(HEADER_PREFIX) {
                    if let Ok(v) = rest.trim().parse::<u32>() {
                        if v > FORMAT_VERSION {
                            crate::log_warn!(
                                "cache file {} is format v{v} (this build writes \
                                 v{FORMAT_VERSION}); loading best-effort",
                                path.display()
                            );
                        }
                    }
                    continue;
                }
                if line.trim_start().starts_with('#') {
                    continue; // comments
                }
                let parts: Vec<&str> = line.split_whitespace().collect();
                if parts.len() != 7 {
                    if !line.trim().is_empty() {
                        crate::log_warn!("skipping malformed cache line: {line:?}");
                    }
                    continue;
                }
                let parse = || -> Option<(CacheKey, SortParams)> {
                    let band: u32 = parts[0].parse().ok()?;
                    let mut genes = [0i64; 5];
                    for (i, g) in genes.iter_mut().enumerate() {
                        *g = parts[2 + i].parse().ok()?;
                    }
                    if !bounds.validate(&genes) {
                        return None;
                    }
                    Some((
                        CacheKey { size_band: band, dist: parts[1].to_string() },
                        SortParams::from_genes(&genes),
                    ))
                };
                match parse() {
                    Some((k, p)) => {
                        if !looks_like_fingerprint_label(&k.dist) {
                            legacy_keys += 1;
                        }
                        map.insert(k, p);
                    }
                    None => crate::log_warn!("skipping unparseable cache line: {line:?}"),
                }
            }
        }
        if legacy_keys > 0 {
            // v1 files keyed on declared distribution names still load (the
            // string-keyed get/put API serves them), but the service resolves
            // through fingerprint labels, so such entries are never served.
            crate::log_warn!(
                "{legacy_keys} cache entries in {} use legacy (non-fingerprint) keys; \
                 fingerprint-based resolution will not serve them",
                path.display()
            );
        }
        Ok(cache)
    }
}

/// Does a cache key string look like a [`Fingerprint::label`]
/// (`b<band>:<runs>:<dups>:w<bytes>:<signs>`, optionally suffixed with a
/// dtype tag segment such as `:f64`) rather than a legacy v1 distribution
/// name?
///
/// [`Fingerprint::label`]: crate::autotune::Fingerprint::label
fn looks_like_fingerprint_label(key: &str) -> bool {
    key.starts_with('b') && matches!(key.split(':').count(), 5 | 6)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn banding_half_decades() {
        assert_eq!(CacheKey::band_of(1), 0);
        assert_eq!(CacheKey::band_of(10), 2);
        assert_eq!(CacheKey::band_of(31_623), 9); // 10^4.5
        assert_eq!(CacheKey::band_of(10_000_000), 14);
        // Same band for nearby sizes, different across half-decades.
        assert_eq!(CacheKey::band_of(1_000_000), CacheKey::band_of(2_000_000));
        assert_ne!(CacheKey::band_of(1_000_000), CacheKey::band_of(5_000_000));
    }

    #[test]
    fn put_get_same_band() {
        let c = TuningCache::new();
        assert!(c.get(1_000_000, "uniform").is_none());
        c.put(1_000_000, "uniform", SortParams::paper_1e7());
        assert_eq!(c.get(1_200_000, "uniform"), Some(SortParams::paper_1e7()));
        assert!(c.get(1_200_000, "zipf").is_none(), "distribution is part of the key");
        assert!(c.get(100_000_000, "uniform").is_none(), "band mismatch");
    }

    #[test]
    fn save_load_roundtrip() {
        let c = TuningCache::new();
        c.put(10_000_000, "uniform", SortParams::paper_1e7());
        c.put(100_000_000, "zipf", SortParams::paper_1e8());
        let path = std::env::temp_dir().join(format!("evosort-cache-{}.txt", std::process::id()));
        c.save(&path).unwrap();
        let loaded = TuningCache::load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded.get(10_000_000, "uniform"), Some(SortParams::paper_1e7()));
        assert_eq!(loaded.get(100_000_000, "zipf"), Some(SortParams::paper_1e8()));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_skips_corrupt_lines() {
        let path = std::env::temp_dir().join(format!("evosort-cache-bad-{}.txt", std::process::id()));
        std::fs::write(&path, "garbage line\n14 uniform 3075 31291 4 99574 1418\n1 2 3\n").unwrap();
        let loaded = TuningCache::load(&path).unwrap();
        assert_eq!(loaded.len(), 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn save_writes_versioned_header_and_legacy_v1_loads() {
        let c = TuningCache::new();
        c.put(10_000_000, "b14:mix:uniq:w4:pm", SortParams::paper_1e7());
        let path =
            std::env::temp_dir().join(format!("evosort-cache-v2-{}.txt", std::process::id()));
        c.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(
            text.starts_with(&format!("{HEADER_PREFIX}{FORMAT_VERSION}\n")),
            "missing version header: {text:?}"
        );
        // Headerless v1 content (the PR-1 format) still loads.
        std::fs::write(&path, "14 uniform 3075 31291 4 99574 1418\n").unwrap();
        let v1 = TuningCache::load(&path).unwrap();
        assert_eq!(v1.get(10_000_000, "uniform"), Some(SortParams::paper_1e7()));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_skips_out_of_bounds_and_truncated_genes() {
        let path =
            std::env::temp_dir().join(format!("evosort-cache-oob-{}.txt", std::process::id()));
        // Line 1: insertion threshold far outside any writer's bounds (bit
        // flip / truncation damage) — must be skipped, NOT clamped into a
        // plausible-looking value. Line 2: truncated final line. Line 3: ok.
        std::fs::write(
            &path,
            "14 uniform 999999999 31291 4 99574 1418\n14 zipf 3075 31291 4 995\n12 ok 3075 31291 4 99574 1418",
        )
        .unwrap();
        let loaded = TuningCache::load(&path).unwrap();
        assert_eq!(loaded.len(), 1);
        assert_eq!(loaded.get(1_000_000, "ok"), Some(SortParams::paper_1e7()));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn future_version_header_loads_best_effort() {
        let path =
            std::env::temp_dir().join(format!("evosort-cache-v9-{}.txt", std::process::id()));
        std::fs::write(&path, "# evosort-tuning-cache v9\n14 x 3075 31291 4 99574 1418\n")
            .unwrap();
        let loaded = TuningCache::load(&path).unwrap();
        assert_eq!(loaded.len(), 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn absorb_merges_entries() {
        let live = TuningCache::new();
        live.put(1_000_000, "a", SortParams::paper_1e7());
        let persisted = TuningCache::new();
        persisted.put(1_000_000, "b", SortParams::paper_1e8());
        persisted.put(1_000_000, "a", SortParams::paper_1e9()); // overwrite
        assert_eq!(live.absorb(&persisted), 2);
        assert_eq!(live.len(), 2);
        assert_eq!(live.get(1_000_000, "a"), Some(SortParams::paper_1e9()));
        assert_eq!(live.get(1_000_000, "b"), Some(SortParams::paper_1e8()));
        assert_eq!(live.entries().len(), 2);
    }
}
