//! Tuning cache: remembers GA results per workload class so repeat sorts pay
//! zero tuning overhead (the gap §7 of the paper addresses with symbolic
//! models; the cache is the service-side complement).
//!
//! Keys are `(size_band, distribution)` — the size band is the integer part
//! of log10(n) · 2 (half-decade bands), since tuned thresholds vary smoothly
//! in log10 n (paper §7). Persistence is a plain text file (no serde crate
//! offline): `band dist genes...` per line.

use std::collections::HashMap;
use std::path::Path;
use std::sync::RwLock;

use anyhow::{Context, Result};

use crate::params::SortParams;

/// Workload class key.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub size_band: u32,
    pub dist: String,
}

impl CacheKey {
    /// Half-decade size banding: n ∈ [10^(b/2), 10^((b+1)/2)).
    pub fn band_of(n: usize) -> u32 {
        ((n.max(1) as f64).log10() * 2.0).floor() as u32
    }

    pub fn new(n: usize, dist: &str) -> CacheKey {
        CacheKey { size_band: Self::band_of(n), dist: dist.to_string() }
    }
}

/// Thread-safe tuned-parameter cache with text persistence.
#[derive(Default)]
pub struct TuningCache {
    map: RwLock<HashMap<CacheKey, SortParams>>,
}

impl TuningCache {
    pub fn new() -> Self {
        TuningCache::default()
    }

    pub fn get(&self, n: usize, dist: &str) -> Option<SortParams> {
        self.map.read().unwrap().get(&CacheKey::new(n, dist)).copied()
    }

    pub fn put(&self, n: usize, dist: &str, params: SortParams) {
        self.map.write().unwrap().insert(CacheKey::new(n, dist), params);
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Persist as `band dist g0 g1 g2 g3 g4` lines.
    pub fn save(&self, path: &Path) -> Result<()> {
        let map = self.map.read().unwrap();
        let mut lines: Vec<String> = map
            .iter()
            .map(|(k, p)| {
                let g = p.to_genes();
                format!(
                    "{} {} {} {} {} {} {}",
                    k.size_band, k.dist, g[0], g[1], g[2], g[3], g[4]
                )
            })
            .collect();
        lines.sort();
        std::fs::write(path, lines.join("\n") + "\n")
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Load from the text format; unknown/corrupt lines are skipped with a
    /// warning rather than failing the whole cache.
    pub fn load(path: &Path) -> Result<TuningCache> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let cache = TuningCache::new();
        {
            let mut map = cache.map.write().unwrap();
            for line in text.lines() {
                let parts: Vec<&str> = line.split_whitespace().collect();
                if parts.len() != 7 {
                    if !line.trim().is_empty() {
                        crate::log_warn!("skipping malformed cache line: {line:?}");
                    }
                    continue;
                }
                let parse = || -> Option<(CacheKey, SortParams)> {
                    let band: u32 = parts[0].parse().ok()?;
                    let mut genes = [0i64; 5];
                    for (i, g) in genes.iter_mut().enumerate() {
                        *g = parts[2 + i].parse().ok()?;
                    }
                    Some((
                        CacheKey { size_band: band, dist: parts[1].to_string() },
                        SortParams::from_genes(&genes),
                    ))
                };
                match parse() {
                    Some((k, p)) => {
                        map.insert(k, p);
                    }
                    None => crate::log_warn!("skipping unparseable cache line: {line:?}"),
                }
            }
        }
        Ok(cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn banding_half_decades() {
        assert_eq!(CacheKey::band_of(1), 0);
        assert_eq!(CacheKey::band_of(10), 2);
        assert_eq!(CacheKey::band_of(31_623), 9); // 10^4.5
        assert_eq!(CacheKey::band_of(10_000_000), 14);
        // Same band for nearby sizes, different across half-decades.
        assert_eq!(CacheKey::band_of(1_000_000), CacheKey::band_of(2_000_000));
        assert_ne!(CacheKey::band_of(1_000_000), CacheKey::band_of(5_000_000));
    }

    #[test]
    fn put_get_same_band() {
        let c = TuningCache::new();
        assert!(c.get(1_000_000, "uniform").is_none());
        c.put(1_000_000, "uniform", SortParams::paper_1e7());
        assert_eq!(c.get(1_200_000, "uniform"), Some(SortParams::paper_1e7()));
        assert!(c.get(1_200_000, "zipf").is_none(), "distribution is part of the key");
        assert!(c.get(100_000_000, "uniform").is_none(), "band mismatch");
    }

    #[test]
    fn save_load_roundtrip() {
        let c = TuningCache::new();
        c.put(10_000_000, "uniform", SortParams::paper_1e7());
        c.put(100_000_000, "zipf", SortParams::paper_1e8());
        let path = std::env::temp_dir().join(format!("evosort-cache-{}.txt", std::process::id()));
        c.save(&path).unwrap();
        let loaded = TuningCache::load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded.get(10_000_000, "uniform"), Some(SortParams::paper_1e7()));
        assert_eq!(loaded.get(100_000_000, "zipf"), Some(SortParams::paper_1e8()));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_skips_corrupt_lines() {
        let path = std::env::temp_dir().join(format!("evosort-cache-bad-{}.txt", std::process::id()));
        std::fs::write(&path, "garbage line\n14 uniform 3075 31291 4 99574 1418\n1 2 3\n").unwrap();
        let loaded = TuningCache::load(&path).unwrap();
        assert_eq!(loaded.len(), 1);
        std::fs::remove_file(&path).unwrap();
    }
}
