//! Tuning cache: remembers GA results per workload class so repeat sorts pay
//! zero tuning overhead (the gap §7 of the paper addresses with symbolic
//! models; the cache is the service-side complement).
//!
//! Keys are `(size_band, class)` — the size band is the integer part of
//! log10(n) · 2 (half-decade bands), since tuned thresholds vary smoothly in
//! log10 n (paper §7). The class string is a workload **fingerprint** label
//! ([`Fingerprint::label`](crate::autotune::Fingerprint::label)) computed
//! from the job's actual data — *not* the caller-declared distribution name,
//! which the service previously trusted and which let one mislabeled job
//! poison the cache for its whole size band.
//!
//! Entries optionally carry the **fitness** (seconds on the class's retained
//! sample) they were published with. Fitness is what makes
//! [`TuningCache::absorb`] *improvement-aware*: when two caches hold the same
//! key — a router merging shard publications, a restart restoring a persisted
//! file over live state — the better-measured entry wins instead of the
//! last writer, so a well-tuned class can never be clobbered by a worse one.
//!
//! Persistence is a versioned plain text file (no serde crate offline): a
//! `# evosort-tuning-cache v4` header followed by
//! `band class g0 g1 g2 g3 g4 g5 [fitness] [x<run>,<fan>,<spill>]` lines (the
//! fitness column is optional for back-compat; the `x`-prefixed column, new
//! in v3, carries the out-of-core spill genes of beyond-memory classes; the
//! sixth gene column `g5`, new in v4, is the radix digit width — files from
//! earlier writers carry five gene columns and load with the default width).
//! The same text form is the cross-process interchange format the sharded
//! service broadcasts over its control channel ([`TuningCache::to_text`] /
//! [`TuningCache::from_text`]). Loading is forgiving: corrupt, truncated,
//! or out-of-bounds lines are skipped with a warning, never propagated as
//! `Err` or bad genes.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use anyhow::{Context, Result};

use crate::extsort::{ExtBounds, ExtParams};
use crate::params::{Bounds, RadixWidth, SortParams};

/// Current on-disk format version (see [`TuningCache::save`]).
pub const FORMAT_VERSION: u32 = 4;

const HEADER_PREFIX: &str = "# evosort-tuning-cache v";

/// Workload class key.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub size_band: u32,
    pub dist: String,
}

impl CacheKey {
    /// Half-decade size banding: n ∈ [10^(b/2), 10^((b+1)/2)).
    pub fn band_of(n: usize) -> u32 {
        ((n.max(1) as f64).log10() * 2.0).floor() as u32
    }

    pub fn new(n: usize, dist: &str) -> CacheKey {
        CacheKey { size_band: Self::band_of(n), dist: dist.to_string() }
    }
}

/// One cached tuning result: parameters plus, when known, the fitness
/// (seconds on the class's retained sample) they were published with.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheEntry {
    pub params: SortParams,
    /// Measured fitness recorded at publish time; `None` for explicit
    /// [`TuningCache::put`]s and legacy persisted files. Lower is better.
    pub fitness: Option<f64>,
    /// Out-of-core spill genes (`run_size`/`merge_fan_in`/`spill_threshold`)
    /// for beyond-memory (`:xm`) classes; `None` for in-RAM classes.
    pub ext: Option<ExtParams>,
}

/// Thread-safe tuned-parameter cache with text persistence.
#[derive(Default)]
pub struct TuningCache {
    map: RwLock<HashMap<CacheKey, CacheEntry>>,
    /// Bumped on every mutation that changed the map — cheap change
    /// detection for the shard workers' periodic cache publication.
    version: AtomicU64,
}

impl TuningCache {
    pub fn new() -> Self {
        TuningCache::default()
    }

    pub fn get(&self, n: usize, dist: &str) -> Option<SortParams> {
        self.map.read().unwrap().get(&CacheKey::new(n, dist)).map(|e| e.params)
    }

    /// The full entry (parameters + recorded fitness) for a key.
    pub fn entry(&self, n: usize, dist: &str) -> Option<CacheEntry> {
        self.map.read().unwrap().get(&CacheKey::new(n, dist)).copied()
    }

    /// Insert with no recorded fitness (explicit pre-warm / override path).
    /// Unconditional: an explicit put expresses operator intent.
    pub fn put(&self, n: usize, dist: &str, params: SortParams) {
        let entry = CacheEntry { params, fitness: None, ext: None };
        self.map.write().unwrap().insert(CacheKey::new(n, dist), entry);
        self.version.fetch_add(1, Ordering::Relaxed);
    }

    /// Insert with the measured fitness the parameters were published with
    /// (the online tuner's path). Non-finite fitness is stored as unknown.
    pub fn put_with_fitness(&self, n: usize, dist: &str, params: SortParams, fitness: f64) {
        let fitness = (fitness.is_finite() && fitness >= 0.0).then_some(fitness);
        let entry = CacheEntry { params, fitness, ext: None };
        self.map.write().unwrap().insert(CacheKey::new(n, dist), entry);
        self.version.fetch_add(1, Ordering::Relaxed);
    }

    /// Spill genes recorded for a beyond-memory class, if any.
    pub fn get_ext(&self, n: usize, dist: &str) -> Option<ExtParams> {
        self.map.read().unwrap().get(&CacheKey::new(n, dist)).and_then(|e| e.ext)
    }

    /// Insert sort parameters **plus** out-of-core spill genes under a
    /// beyond-memory class (the ext-tuner's publish path). Non-finite
    /// fitness is stored as unknown, same as [`TuningCache::put_with_fitness`].
    pub fn put_ext_with_fitness(
        &self,
        n: usize,
        dist: &str,
        params: SortParams,
        ext: ExtParams,
        fitness: f64,
    ) {
        let fitness = (fitness.is_finite() && fitness >= 0.0).then_some(fitness);
        let entry = CacheEntry { params, fitness, ext: Some(ext) };
        self.map.write().unwrap().insert(CacheKey::new(n, dist), entry);
        self.version.fetch_add(1, Ordering::Relaxed);
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Monotone change counter (bumped whenever the map changed).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Relaxed)
    }

    /// Snapshot of every entry (for reports and tests).
    pub fn entries(&self) -> Vec<(CacheKey, SortParams)> {
        self.map.read().unwrap().iter().map(|(k, e)| (k.clone(), e.params)).collect()
    }

    /// Merge `other` into this cache, **improvement-aware**: when both
    /// caches hold a key, the entry with the better (lower) recorded fitness
    /// wins; a measured entry beats an unmeasured one; an unmeasured
    /// incoming entry never clobbers a measured local one. Two unmeasured
    /// entries keep the historical last-writer-wins behaviour (the restore
    /// path absorbs persisted parameters over an empty live cache).
    ///
    /// Returns the number of entries actually inserted or replaced — the
    /// sharded router uses "absorbed > 0" as its re-broadcast trigger.
    pub fn absorb(&self, other: &TuningCache) -> usize {
        let theirs = other.map.read().unwrap();
        let mut ours = self.map.write().unwrap();
        let mut changed = 0usize;
        for (k, incoming) in theirs.iter() {
            let replace = match ours.get(k) {
                None => true,
                Some(local) => match (incoming.fitness, local.fitness) {
                    (Some(fi), Some(fl)) => fi < fl,
                    (Some(_), None) => true,
                    (None, Some(_)) => false,
                    (None, None) => local != incoming,
                },
            };
            if replace {
                ours.insert(k.clone(), *incoming);
                changed += 1;
            }
        }
        drop(ours);
        if changed > 0 {
            self.version.fetch_add(1, Ordering::Relaxed);
        }
        changed
    }

    /// Serialize to the versioned text format: a header plus
    /// `band class g0 g1 g2 g3 g4 g5 [fitness] [x<run>,<fan>,<spill>]` lines.
    /// This is both the on-disk format ([`TuningCache::save`]) and the
    /// cross-process interchange the sharded service ships over its control
    /// channel. The `x`-prefixed spill-gene column is position-independent
    /// of the fitness column: the parser disambiguates on the prefix, so an
    /// ext entry without fitness is still representable.
    pub fn to_text(&self) -> String {
        let map = self.map.read().unwrap();
        let mut lines: Vec<String> = map
            .iter()
            .map(|(k, e)| {
                let g = e.params.to_genes();
                let mut line = format!(
                    "{} {} {} {} {} {} {} {}",
                    k.size_band, k.dist, g[0], g[1], g[2], g[3], g[4], g[5]
                );
                if let Some(f) = e.fitness {
                    line.push_str(&format!(" {f:.9e}"));
                }
                if let Some(x) = e.ext {
                    let xg = x.to_genes();
                    line.push_str(&format!(" x{},{},{}", xg[0], xg[1], xg[2]));
                }
                line
            })
            .collect();
        lines.sort();
        format!("{HEADER_PREFIX}{FORMAT_VERSION}\n{}\n", lines.join("\n"))
    }

    /// Parse the text format (headered v2/v3/v4 or legacy headerless v1;
    /// the header version selects the gene-column count — five for pre-v4
    /// writers, whose entries load with the default radix width, six for v4.
    /// Trailing fitness-only lines load with unknown fitness, `x`-prefixed
    /// trailing columns load as spill genes). Corrupt, truncated, or
    /// out-of-bounds lines are skipped with a warning rather than failing
    /// the whole cache or clamping garbage genes into plausible-looking
    /// parameters.
    pub fn from_text(text: &str) -> TuningCache {
        let cache = TuningCache::new();
        // The widest bounds any writer could have used: a persisted genome
        // outside them is corruption, not tuning.
        let bounds = Bounds::with_all_strategies();
        let ext_bounds = ExtBounds::default();
        let mut legacy_keys = 0usize;
        // Headerless files are the PR-1 v1 format: five gene columns.
        let mut gene_cols = 5usize;
        {
            let mut map = cache.map.write().unwrap();
            for line in text.lines() {
                if let Some(rest) = line.strip_prefix(HEADER_PREFIX) {
                    if let Ok(v) = rest.trim().parse::<u32>() {
                        // v4 grew the radix-width gene column; an unknown
                        // future version is assumed to share v4's layout.
                        gene_cols = if v >= 4 { 6 } else { 5 };
                        if v > FORMAT_VERSION {
                            crate::log_warn!(
                                "cache data is format v{v} (this build writes \
                                 v{FORMAT_VERSION}); loading best-effort"
                            );
                        }
                    }
                    continue;
                }
                if line.trim_start().starts_with('#') {
                    continue; // comments
                }
                let base = 2 + gene_cols;
                let parts: Vec<&str> = line.split_whitespace().collect();
                if !(base..=base + 2).contains(&parts.len()) {
                    if !line.trim().is_empty() {
                        crate::log_warn!("skipping malformed cache line: {line:?}");
                    }
                    continue;
                }
                let parse = || -> Option<(CacheKey, CacheEntry)> {
                    let band: u32 = parts[0].parse().ok()?;
                    // Pre-v4 lines have no width column: imply the default.
                    let mut genes = [0i64; 6];
                    genes[5] = RadixWidth::default().gene();
                    for (i, g) in genes.iter_mut().enumerate().take(gene_cols) {
                        *g = parts[2 + i].parse().ok()?;
                    }
                    if !bounds.validate(&genes) {
                        return None;
                    }
                    let mut fitness = None;
                    let mut ext = None;
                    for (pos, tok) in parts[base..].iter().enumerate() {
                        if let Some(xg) = tok.strip_prefix('x') {
                            if ext.is_some() {
                                return None; // duplicate spill-gene column
                            }
                            let mut eg = [0i64; 3];
                            let mut it = xg.split(',');
                            for g in eg.iter_mut() {
                                *g = it.next()?.parse().ok()?;
                            }
                            if it.next().is_some() || !ext_bounds.validate(&eg) {
                                return None;
                            }
                            ext = Some(ExtParams::from_genes(&eg));
                        } else {
                            if pos != 0 {
                                return None; // fitness must precede the x column
                            }
                            let f: f64 = tok.parse().ok()?;
                            if !(f.is_finite() && f >= 0.0) {
                                return None;
                            }
                            fitness = Some(f);
                        }
                    }
                    Some((
                        CacheKey { size_band: band, dist: parts[1].to_string() },
                        CacheEntry { params: SortParams::from_genes(&genes), fitness, ext },
                    ))
                };
                match parse() {
                    Some((k, e)) => {
                        if !looks_like_fingerprint_label(&k.dist) {
                            legacy_keys += 1;
                        }
                        map.insert(k, e);
                    }
                    None => crate::log_warn!("skipping unparseable cache line: {line:?}"),
                }
            }
        }
        if legacy_keys > 0 {
            // v1 files keyed on declared distribution names still load (the
            // string-keyed get/put API serves them), but the service resolves
            // through fingerprint labels, so such entries are never served.
            crate::log_warn!(
                "{legacy_keys} cache entries use legacy (non-fingerprint) keys; \
                 fingerprint-based resolution will not serve them"
            );
        }
        cache
    }

    /// Persist as a versioned header plus entry lines (see
    /// [`TuningCache::to_text`]).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_text())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Load from the text format (see [`TuningCache::from_text`]).
    pub fn load(path: &Path) -> Result<TuningCache> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Ok(TuningCache::from_text(&text))
    }
}

/// Does a cache key string look like a [`Fingerprint::label`]
/// (`b<band>:<runs>:<dups>:w<bytes>:<signs>`, optionally suffixed with a
/// dtype tag segment such as `:f64` and/or the beyond-memory `:xm` tag)
/// rather than a legacy v1 distribution name?
///
/// [`Fingerprint::label`]: crate::autotune::Fingerprint::label
fn looks_like_fingerprint_label(key: &str) -> bool {
    key.starts_with('b') && matches!(key.split(':').count(), 5 | 6 | 7)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn banding_half_decades() {
        assert_eq!(CacheKey::band_of(1), 0);
        assert_eq!(CacheKey::band_of(10), 2);
        assert_eq!(CacheKey::band_of(31_623), 9); // 10^4.5
        assert_eq!(CacheKey::band_of(10_000_000), 14);
        // Same band for nearby sizes, different across half-decades.
        assert_eq!(CacheKey::band_of(1_000_000), CacheKey::band_of(2_000_000));
        assert_ne!(CacheKey::band_of(1_000_000), CacheKey::band_of(5_000_000));
    }

    #[test]
    fn put_get_same_band() {
        let c = TuningCache::new();
        assert!(c.get(1_000_000, "uniform").is_none());
        c.put(1_000_000, "uniform", SortParams::paper_1e7());
        assert_eq!(c.get(1_200_000, "uniform"), Some(SortParams::paper_1e7()));
        assert!(c.get(1_200_000, "zipf").is_none(), "distribution is part of the key");
        assert!(c.get(100_000_000, "uniform").is_none(), "band mismatch");
    }

    #[test]
    fn version_tracks_changes() {
        let c = TuningCache::new();
        let v0 = c.version();
        c.put(1_000_000, "a", SortParams::paper_1e7());
        assert!(c.version() > v0);
        let v1 = c.version();
        // An absorb that changes nothing does not bump the version.
        let same = TuningCache::new();
        same.put(1_000_000, "a", SortParams::paper_1e7());
        assert_eq!(c.absorb(&same), 0);
        assert_eq!(c.version(), v1);
    }

    #[test]
    fn save_load_roundtrip_with_fitness() {
        let c = TuningCache::new();
        c.put(10_000_000, "uniform", SortParams::paper_1e7());
        c.put_with_fitness(100_000_000, "zipf", SortParams::paper_1e8(), 0.0421);
        let path = std::env::temp_dir().join(format!("evosort-cache-{}.txt", std::process::id()));
        c.save(&path).unwrap();
        let loaded = TuningCache::load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded.get(10_000_000, "uniform"), Some(SortParams::paper_1e7()));
        assert_eq!(loaded.entry(10_000_000, "uniform").unwrap().fitness, None);
        let zipf = loaded.entry(100_000_000, "zipf").unwrap();
        assert_eq!(zipf.params, SortParams::paper_1e8());
        assert!((zipf.fitness.unwrap() - 0.0421).abs() < 1e-9);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_skips_corrupt_lines() {
        let path = std::env::temp_dir().join(format!("evosort-cache-bad-{}.txt", std::process::id()));
        std::fs::write(&path, "garbage line\n14 uniform 3075 31291 4 99574 1418\n1 2 3\n").unwrap();
        let loaded = TuningCache::load(&path).unwrap();
        assert_eq!(loaded.len(), 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_skips_bad_fitness_column() {
        // A non-numeric or negative fitness column is corruption: skip the
        // line entirely rather than inventing an unmeasured entry.
        let c = TuningCache::from_text(
            "14 a 3075 31291 4 99574 1418 nonsense\n\
             14 b 3075 31291 4 99574 1418 -1.0\n\
             14 c 3075 31291 4 99574 1418 4.2e-3\n",
        );
        assert_eq!(c.len(), 1);
        assert!((c.entry(10_000_000, "c").unwrap().fitness.unwrap() - 4.2e-3).abs() < 1e-12);
    }

    #[test]
    fn save_writes_versioned_header_and_legacy_v1_loads() {
        let c = TuningCache::new();
        c.put(10_000_000, "b14:mix:uniq:w4:pm", SortParams::paper_1e7());
        let path =
            std::env::temp_dir().join(format!("evosort-cache-v2-{}.txt", std::process::id()));
        c.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(
            text.starts_with(&format!("{HEADER_PREFIX}{FORMAT_VERSION}\n")),
            "missing version header: {text:?}"
        );
        // Headerless v1 content (the PR-1 format) still loads.
        std::fs::write(&path, "14 uniform 3075 31291 4 99574 1418\n").unwrap();
        let v1 = TuningCache::load(&path).unwrap();
        assert_eq!(v1.get(10_000_000, "uniform"), Some(SortParams::paper_1e7()));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_skips_out_of_bounds_and_truncated_genes() {
        let path =
            std::env::temp_dir().join(format!("evosort-cache-oob-{}.txt", std::process::id()));
        // Line 1: insertion threshold far outside any writer's bounds (bit
        // flip / truncation damage) — must be skipped, NOT clamped into a
        // plausible-looking value. Line 2: truncated final line. Line 3: ok.
        std::fs::write(
            &path,
            "14 uniform 999999999 31291 4 99574 1418\n14 zipf 3075 31291 4 995\n12 ok 3075 31291 4 99574 1418",
        )
        .unwrap();
        let loaded = TuningCache::load(&path).unwrap();
        assert_eq!(loaded.len(), 1);
        assert_eq!(loaded.get(1_000_000, "ok"), Some(SortParams::paper_1e7()));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn future_version_header_loads_best_effort() {
        // An unknown future version is assumed to share v4's six-gene layout.
        let loaded =
            TuningCache::from_text("# evosort-tuning-cache v9\n14 x 3075 31291 4 99574 1418 8\n");
        assert_eq!(loaded.len(), 1);
    }

    #[test]
    fn pre_v4_files_load_with_default_radix_width() {
        // Regression: every pre-v4 wire form (five gene columns) must keep
        // loading, with the radix width defaulting to W8. Covers a headered
        // v3 line with fitness + spill columns and a headerless v1 line.
        let v3 = TuningCache::from_text(
            "# evosort-tuning-cache v3\n\
             14 b14:mix:uniq:w4:pm:xm 3075 31291 4 99574 1418 4.2e-3 x2097152,16,0\n",
        );
        assert_eq!(v3.len(), 1);
        let e = v3.entry(10_000_000, "b14:mix:uniq:w4:pm:xm").unwrap();
        assert_eq!(e.params, SortParams::paper_1e7());
        assert_eq!(e.params.radix_width, RadixWidth::W8);
        assert!((e.fitness.unwrap() - 4.2e-3).abs() < 1e-12);
        assert!(e.ext.is_some());

        let v1 = TuningCache::from_text("14 uniform 3075 31291 4 99574 1418\n");
        assert_eq!(v1.get(10_000_000, "uniform").unwrap().radix_width, RadixWidth::W8);
    }

    #[test]
    fn radix_width_gene_roundtrips_through_text() {
        let tuned = SortParams { radix_width: RadixWidth::W11, ..SortParams::paper_1e7() };
        let c = TuningCache::new();
        c.put_with_fitness(10_000_000, "b14:mix:uniq:w8:pm", tuned, 0.01);
        let text = c.to_text();
        let back = TuningCache::from_text(&text);
        let got = back.get(10_000_000, "b14:mix:uniq:w8:pm").unwrap();
        assert_eq!(got.radix_width, RadixWidth::W11);
        assert_eq!(got, tuned);
    }

    #[test]
    fn absorb_merges_entries() {
        let live = TuningCache::new();
        live.put(1_000_000, "a", SortParams::paper_1e7());
        let persisted = TuningCache::new();
        persisted.put(1_000_000, "b", SortParams::paper_1e8());
        persisted.put(1_000_000, "a", SortParams::paper_1e9()); // overwrite (both unmeasured)
        assert_eq!(live.absorb(&persisted), 2);
        assert_eq!(live.len(), 2);
        assert_eq!(live.get(1_000_000, "a"), Some(SortParams::paper_1e9()));
        assert_eq!(live.get(1_000_000, "b"), Some(SortParams::paper_1e8()));
        assert_eq!(live.entries().len(), 2);
    }

    #[test]
    fn absorb_is_improvement_aware() {
        // Regression test for the last-writer-wins merge bug: a worse
        // incoming entry must not clobber a better-tuned local one.
        let live = TuningCache::new();
        live.put_with_fitness(1_000_000, "a", SortParams::paper_1e7(), 0.010);
        let incoming = TuningCache::new();
        incoming.put_with_fitness(1_000_000, "a", SortParams::paper_1e9(), 0.050);
        assert_eq!(live.absorb(&incoming), 0, "worse fitness must not be absorbed");
        assert_eq!(live.get(1_000_000, "a"), Some(SortParams::paper_1e7()));
        assert!((live.entry(1_000_000, "a").unwrap().fitness.unwrap() - 0.010).abs() < 1e-12);

        // A better incoming entry replaces.
        let better = TuningCache::new();
        better.put_with_fitness(1_000_000, "a", SortParams::paper_1e8(), 0.004);
        assert_eq!(live.absorb(&better), 1);
        assert_eq!(live.get(1_000_000, "a"), Some(SortParams::paper_1e8()));

        // An unmeasured incoming entry never clobbers a measured local one…
        let unmeasured = TuningCache::new();
        unmeasured.put(1_000_000, "a", SortParams::paper_1e9());
        assert_eq!(live.absorb(&unmeasured), 0);
        assert_eq!(live.get(1_000_000, "a"), Some(SortParams::paper_1e8()));

        // …while a measured incoming entry beats an unmeasured local one.
        let live2 = TuningCache::new();
        live2.put(1_000_000, "a", SortParams::paper_1e9());
        let measured = TuningCache::new();
        measured.put_with_fitness(1_000_000, "a", SortParams::paper_1e7(), 0.02);
        assert_eq!(live2.absorb(&measured), 1);
        assert_eq!(live2.get(1_000_000, "a"), Some(SortParams::paper_1e7()));
    }

    #[test]
    fn text_roundtrip_is_lossless_for_the_wire() {
        let c = TuningCache::new();
        c.put_with_fitness(50_000, "b9:mix:uniq:w4:pm", SortParams::paper_1e7(), 1.25e-4);
        c.put(5_000_000, "b13:mix:uniq:w8:pm:f64", SortParams::paper_1e8());
        let text = c.to_text();
        let back = TuningCache::from_text(&text);
        assert_eq!(back.len(), 2);
        assert_eq!(back.get(50_000, "b9:mix:uniq:w4:pm"), Some(SortParams::paper_1e7()));
        assert!(
            (back.entry(50_000, "b9:mix:uniq:w4:pm").unwrap().fitness.unwrap() - 1.25e-4).abs()
                < 1e-12
        );
        assert_eq!(back.entry(5_000_000, "b13:mix:uniq:w8:pm:f64").unwrap().fitness, None);
        // Round-tripping again is a fixed point.
        assert_eq!(back.to_text(), text);
    }

    #[test]
    fn ext_genes_roundtrip_through_text() {
        let xm = "b14:mix:uniq:w8:pm:xm";
        let ext = ExtParams { run_size: 1 << 20, merge_fan_in: 8, spill_threshold: 5_000_000 };
        let c = TuningCache::new();
        c.put_ext_with_fitness(10_000_000, xm, SortParams::paper_1e7(), ext, 0.37);
        assert_eq!(c.get_ext(10_000_000, xm), Some(ext));
        assert!(c.get_ext(10_000_000, "b14:mix:uniq:w8:pm").is_none());

        let text = c.to_text();
        assert!(text.contains(" x1048576,8,5000000"), "missing spill column: {text:?}");
        let back = TuningCache::from_text(&text);
        assert_eq!(back.get_ext(10_000_000, xm), Some(ext));
        assert!((back.entry(10_000_000, xm).unwrap().fitness.unwrap() - 0.37).abs() < 1e-9);
        // Round-tripping again is a fixed point.
        assert_eq!(back.to_text(), text);
    }

    #[test]
    fn ext_column_without_fitness_parses_and_corrupt_ext_is_skipped() {
        // `x` column directly after the genes (no fitness) is valid.
        let ok = TuningCache::from_text("14 a:xm 3075 31291 4 99574 1418 x2097152,16,0\n");
        assert_eq!(ok.len(), 1);
        let e = ok.entry(10_000_000, "a:xm").unwrap();
        assert_eq!(e.fitness, None);
        assert_eq!(e.ext, Some(ExtParams { run_size: 1 << 21, merge_fan_in: 16, spill_threshold: 0 }));

        // Corrupt spill columns (bad arity, out-of-bounds fan-in, fitness
        // after the x column) are corruption: skip the whole line.
        let bad = TuningCache::from_text(
            "14 b 3075 31291 4 99574 1418 x1,2\n\
             14 c 3075 31291 4 99574 1418 x2097152,9999,0\n\
             14 d 3075 31291 4 99574 1418 x2097152,16,0 0.5\n",
        );
        assert!(bad.is_empty(), "corrupt ext lines must be skipped");
    }

    #[test]
    fn xm_labels_count_as_fingerprint_keys() {
        assert!(looks_like_fingerprint_label("b14:mix:uniq:w8:pm:xm"));
        assert!(looks_like_fingerprint_label("b14:mix:uniq:w8:pm:f64:xm"));
        assert!(!looks_like_fingerprint_label("uniform"));
    }

    #[test]
    fn absorb_carries_ext_genes() {
        let ext = ExtParams { run_size: 1 << 19, merge_fan_in: 4, spill_threshold: 0 };
        let incoming = TuningCache::new();
        incoming.put_ext_with_fitness(10_000_000, "k:xm", SortParams::paper_1e7(), ext, 0.2);
        let live = TuningCache::new();
        assert_eq!(live.absorb(&incoming), 1);
        assert_eq!(live.get_ext(10_000_000, "k:xm"), Some(ext));
    }
}
