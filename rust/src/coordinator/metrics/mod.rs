//! Metrics registry for the sort service: lock-free counters, Welford-backed
//! latency series, gauges, and bounded sample windows for percentile queries
//! (p50/p99 batch latency), all `Send + Sync`.
//!
//! Every registry lock is **poison-tolerant**: a worker thread that panics
//! while holding one (or while the registry is mid-update anywhere on its
//! stack) must not take reporting down with it — the maps hold counters and
//! sample windows, every update of which is valid at any intermediate
//! state, so recovering the guard from a [`PoisonError`] is always safe.
//! Before this, one panicking job could cascade `PoisonError` unwraps
//! through every later `incr`/`report` call in the process.
//!
//! Series names come from the [`names`] registry — production code never
//! spells a metric name inline (enforced by `cargo xtask lint`).

pub mod names;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

use crate::util::stats::Welford;

/// Lock a registry mutex, recovering the guard if a previous holder
/// panicked (see the module docs for why this is safe here).
fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// How many recent samples a percentile window retains per series.
const SAMPLE_WINDOW: usize = 8192;

/// A sliding window of recent f64 observations (ring buffer) supporting
/// percentile queries. Welford summaries cannot answer p99; a bounded window
/// keeps memory O(1) under service-lifetime traffic.
#[derive(Debug, Clone, Default)]
pub struct SampleWindow {
    values: Vec<f64>,
    next: usize,
    total: u64,
}

impl SampleWindow {
    pub fn push(&mut self, x: f64) {
        if self.values.len() < SAMPLE_WINDOW {
            self.values.push(x);
        } else {
            self.values[self.next] = x;
            self.next = (self.next + 1) % SAMPLE_WINDOW;
        }
        self.total += 1;
    }

    /// Observations ever pushed (window holds min(total, capacity)).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Nearest-rank percentile over the retained window; `None` when empty.
    pub fn percentile(&self, q: f64) -> Option<f64> {
        percentile_of_unsorted(&self.values, q)
    }
}

/// Nearest-rank percentile of an unsorted sample set (`q` in [0, 100]).
pub fn percentile_of_unsorted(samples: &[f64], q: f64) -> Option<f64> {
    if samples.is_empty() {
        return None;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    Some(percentile_of_sorted(&sorted, q))
}

/// Nearest-rank percentile of an already-sorted, non-empty sample set.
pub fn percentile_of_sorted(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let n = sorted.len();
    let rank = ((q / 100.0) * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Registry shared across service workers.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<HashMap<String, AtomicU64>>,
    latencies: Mutex<HashMap<String, Welford>>,
    gauges: Mutex<HashMap<String, f64>>,
    samples: Mutex<HashMap<String, SampleWindow>>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, delta: u64) {
        let mut map = locked(&self.counters);
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(delta, Ordering::Relaxed);
    }

    pub fn counter(&self, name: &str) -> u64 {
        locked(&self.counters).get(name).map(|c| c.load(Ordering::Relaxed)).unwrap_or(0)
    }

    /// Snapshot of every counter, sorted by name — the shard workers'
    /// telemetry frames ship this to the router for per-shard aggregation.
    pub fn counters_snapshot(&self) -> Vec<(String, u64)> {
        let map = locked(&self.counters);
        let mut out: Vec<(String, u64)> =
            map.iter().map(|(k, c)| (k.clone(), c.load(Ordering::Relaxed))).collect();
        drop(map);
        out.sort();
        out
    }

    /// `a / (a + b)` over two counters — e.g. the tuning-cache hit rate
    /// from `params.cache_hit` / `params.cache_miss` (the online tuner
    /// publishes it as the `tuner.cache_hit_rate` gauge).
    ///
    /// Returns `None` when the denominator counter `b` has never been
    /// registered (a ratio against a metric that does not exist is
    /// meaningless, not 100%) and when no observation has landed yet
    /// (`a + b == 0`).
    pub fn counter_ratio(&self, a: &str, b: &str) -> Option<f64> {
        let map = locked(&self.counters);
        let b = map.get(b)?.load(Ordering::Relaxed);
        let a = map.get(a).map(|c| c.load(Ordering::Relaxed)).unwrap_or(0);
        drop(map);
        if a + b == 0 {
            None
        } else {
            Some(a as f64 / (a + b) as f64)
        }
    }

    /// Record a latency observation (seconds).
    pub fn observe(&self, name: &str, secs: f64) {
        let mut map = locked(&self.latencies);
        map.entry(name.to_string()).or_insert_with(Welford::new).push(secs);
    }

    /// Snapshot of one latency series.
    pub fn latency(&self, name: &str) -> Option<Welford> {
        locked(&self.latencies).get(name).copied()
    }

    /// Set a gauge (latest-value metric, e.g. `batch.jobs_per_sec`).
    pub fn set_gauge(&self, name: &str, value: f64) {
        locked(&self.gauges).insert(name.to_string(), value);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        locked(&self.gauges).get(name).copied()
    }

    /// Record an observation into a bounded percentile window.
    pub fn observe_sample(&self, name: &str, value: f64) {
        locked(&self.samples).entry(name.to_string()).or_default().push(value);
    }

    /// Nearest-rank percentile (`q` in [0, 100]) over a sample window.
    pub fn percentile(&self, name: &str, q: f64) -> Option<f64> {
        locked(&self.samples).get(name).and_then(|w| w.percentile(q))
    }

    /// Render a human-readable report (CLI `info`/`serve` output).
    pub fn report(&self) -> String {
        let mut out = String::new();
        let counters = locked(&self.counters);
        let mut names: Vec<&String> = counters.keys().collect();
        names.sort();
        for name in names {
            out.push_str(&format!(
                "counter {name} = {}\n",
                counters[name].load(Ordering::Relaxed)
            ));
        }
        drop(counters);
        let lats = locked(&self.latencies);
        let mut names: Vec<&String> = lats.keys().collect();
        names.sort();
        for name in names {
            let w = &lats[name];
            out.push_str(&format!(
                "latency {name}: n={} mean={:.6}s min={:.6}s max={:.6}s stddev={:.6}s\n",
                w.count(),
                w.mean(),
                w.min(),
                w.max(),
                w.stddev()
            ));
        }
        drop(lats);
        let gauges = locked(&self.gauges);
        let mut names: Vec<&String> = gauges.keys().collect();
        names.sort();
        for name in names {
            out.push_str(&format!("gauge {name} = {:.6}\n", gauges[name]));
        }
        drop(gauges);
        let samples = locked(&self.samples);
        let mut names: Vec<&String> = samples.keys().collect();
        names.sort();
        for name in names {
            let w = &samples[name];
            let (p50, p99) = (w.percentile(50.0).unwrap_or(0.0), w.percentile(99.0).unwrap_or(0.0));
            out.push_str(&format!(
                "samples {name}: n={} p50={p50:.6} p99={p99:.6}\n",
                w.total()
            ));
        }
        out
    }

    /// Render every series in the Prometheus text exposition format
    /// (version 0.0.4), deterministically sorted by name.
    ///
    /// Naming: dotted internal names become underscore-separated with an
    /// `evosort_` prefix (`jobs.completed` → `evosort_jobs_completed`,
    /// `kernel.radix.scatter` → `evosort_kernel_radix_scatter`). Counters
    /// and gauges export their value directly; latency series export
    /// `_count`/`_sum`/`_min`/`_max`; sample windows export `quantile`
    /// series (p50/p99 over the retained window) plus `_count`.
    pub fn render_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for (name, value) in self.counters_snapshot() {
            let name = prometheus_name(&name);
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {value}");
        }
        let lats = locked(&self.latencies);
        let mut series: Vec<(String, Welford)> =
            lats.iter().map(|(k, w)| (k.clone(), *w)).collect();
        drop(lats);
        series.sort_by(|a, b| a.0.cmp(&b.0));
        for (name, w) in series {
            let name = prometheus_name(&name);
            let _ = writeln!(out, "# TYPE {name} summary");
            let _ = writeln!(out, "{name}_count {}", w.count());
            let _ = writeln!(out, "{name}_sum {}", prometheus_f64(w.mean() * w.count() as f64));
            let _ = writeln!(out, "{name}_min {}", prometheus_f64(w.min()));
            let _ = writeln!(out, "{name}_max {}", prometheus_f64(w.max()));
        }
        let gauges = locked(&self.gauges);
        let mut series: Vec<(String, f64)> = gauges.iter().map(|(k, v)| (k.clone(), *v)).collect();
        drop(gauges);
        series.sort_by(|a, b| a.0.cmp(&b.0));
        for (name, value) in series {
            let name = prometheus_name(&name);
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {}", prometheus_f64(value));
        }
        let samples = locked(&self.samples);
        let mut series: Vec<(String, SampleWindow)> =
            samples.iter().map(|(k, w)| (k.clone(), w.clone())).collect();
        drop(samples);
        series.sort_by(|a, b| a.0.cmp(&b.0));
        for (name, w) in series {
            let name = prometheus_name(&name);
            let _ = writeln!(out, "# TYPE {name} summary");
            for (q, label) in [(50.0, "0.5"), (99.0, "0.99")] {
                if let Some(v) = w.percentile(q) {
                    let _ = writeln!(out, "{name}{{quantile=\"{label}\"}} {}", prometheus_f64(v));
                }
            }
            let _ = writeln!(out, "{name}_count {}", w.total());
        }
        out
    }
}

/// Map a dotted internal metric name onto the Prometheus charset:
/// `evosort_` prefix, every non-`[a-zA-Z0-9_]` byte replaced with `_`.
fn prometheus_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 8);
    out.push_str("evosort_");
    for ch in name.chars() {
        out.push(if ch.is_ascii_alphanumeric() || ch == '_' { ch } else { '_' });
    }
    out
}

/// Prometheus float formatting: `f64` Display, except the non-finite
/// spellings the exposition format defines.
fn prometheus_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v.is_infinite() {
        if v > 0.0 { "+Inf".to_string() } else { "-Inf".to_string() }
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("jobs");
        m.add("jobs", 4);
        assert_eq!(m.counter("jobs"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn counter_ratio_hit_rate() {
        let m = Metrics::new();
        assert_eq!(m.counter_ratio("hit", "miss"), None);
        m.add("hit", 3);
        m.add("miss", 1);
        assert_eq!(m.counter_ratio("hit", "miss"), Some(0.75));
        assert_eq!(m.counter_ratio("miss", "hit"), Some(0.25));
    }

    #[test]
    fn counter_ratio_absent_denominator_is_none() {
        // A ratio against a counter that was never registered is undefined,
        // not 100%: `hit` alone must not make `hit/(hit+miss)` report 1.0.
        let m = Metrics::new();
        m.add("hit", 3);
        assert_eq!(m.counter_ratio("hit", "miss"), None);
        // The numerator may be absent as long as the denominator exists.
        assert_eq!(m.counter_ratio("miss", "hit"), Some(0.0));
    }

    #[test]
    fn counter_ratio_zero_denominator_is_none() {
        // Registered-but-never-incremented counters (snapshot merges, or
        // `add(name, 0)`) must behave like "no observations yet" too.
        let m = Metrics::new();
        m.add("hit", 0);
        m.add("miss", 0);
        assert_eq!(m.counter_ratio("hit", "miss"), None);
        m.incr("hit");
        assert_eq!(m.counter_ratio("hit", "miss"), Some(1.0));
    }

    #[test]
    fn report_is_sorted_by_name() {
        let m = Metrics::new();
        m.incr("z.last");
        m.incr("a.first");
        m.incr("m.middle");
        m.set_gauge("z.g", 1.0);
        m.set_gauge("a.g", 2.0);
        let r = m.report();
        let a = r.find("counter a.first").unwrap();
        let mid = r.find("counter m.middle").unwrap();
        let z = r.find("counter z.last").unwrap();
        assert!(a < mid && mid < z, "counters must render in name order:\n{r}");
        assert!(r.find("gauge a.g").unwrap() < r.find("gauge z.g").unwrap());
    }

    #[test]
    fn prometheus_rendering() {
        let m = Metrics::new();
        m.incr("jobs.completed");
        m.add("trace.dropped", 7);
        m.set_gauge("router.queue.depth", 3.0);
        m.observe("sort.latency", 0.25);
        m.observe("sort.latency", 0.75);
        for i in 1..=100 {
            m.observe_sample("kernel.radix.scatter", i as f64);
        }
        let text = m.render_prometheus();
        assert!(text.contains("# TYPE evosort_jobs_completed counter"), "{text}");
        assert!(text.contains("evosort_jobs_completed 1\n"), "{text}");
        assert!(text.contains("evosort_trace_dropped 7\n"), "{text}");
        assert!(text.contains("evosort_router_queue_depth 3\n"), "{text}");
        assert!(text.contains("evosort_sort_latency_count 2\n"), "{text}");
        assert!(text.contains("evosort_sort_latency_sum 1\n"), "{text}");
        assert!(text.contains("evosort_sort_latency_min 0.25\n"), "{text}");
        assert!(text.contains("evosort_sort_latency_max 0.75\n"), "{text}");
        assert!(
            text.contains("evosort_kernel_radix_scatter{quantile=\"0.5\"} 50\n"),
            "{text}"
        );
        assert!(
            text.contains("evosort_kernel_radix_scatter{quantile=\"0.99\"} 99\n"),
            "{text}"
        );
        assert!(text.contains("evosort_kernel_radix_scatter_count 100\n"), "{text}");
        // Deterministic: two renders of the same registry are identical.
        assert_eq!(text, m.render_prometheus());
        // Counters render sorted.
        assert!(
            text.find("evosort_jobs_completed 1").unwrap()
                < text.find("evosort_trace_dropped 7").unwrap()
        );
    }

    #[test]
    fn prometheus_name_sanitization() {
        assert_eq!(prometheus_name("jobs.completed"), "evosort_jobs_completed");
        assert_eq!(prometheus_name("shard.0.local.jobs"), "evosort_shard_0_local_jobs");
        assert_eq!(prometheus_name("weird-name space"), "evosort_weird_name_space");
        assert_eq!(prometheus_f64(f64::NAN), "NaN");
        assert_eq!(prometheus_f64(f64::INFINITY), "+Inf");
        assert_eq!(prometheus_f64(f64::NEG_INFINITY), "-Inf");
        assert_eq!(prometheus_f64(0.25), "0.25");
        assert_eq!(prometheus_f64(3.0), "3");
    }

    #[test]
    fn latency_series() {
        let m = Metrics::new();
        m.observe("sort", 0.5);
        m.observe("sort", 1.5);
        let w = m.latency("sort").unwrap();
        assert_eq!(w.count(), 2);
        assert!((w.mean() - 1.0).abs() < 1e-12);
        assert!(m.latency("none").is_none());
    }

    #[test]
    fn concurrent_updates() {
        let m = Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = Arc::clone(&m);
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.incr("hits");
                        m.observe("lat", 0.001);
                    }
                });
            }
        });
        assert_eq!(m.counter("hits"), 8000);
        assert_eq!(m.latency("lat").unwrap().count(), 8000);
    }

    #[test]
    fn report_contains_series() {
        let m = Metrics::new();
        m.incr("a");
        m.observe("b", 2.0);
        m.set_gauge("g", 1.25);
        m.observe_sample("s", 0.5);
        let r = m.report();
        assert!(r.contains("counter a = 1"));
        assert!(r.contains("latency b:"));
        assert!(r.contains("gauge g = 1.250000"));
        assert!(r.contains("samples s: n=1"));
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        assert!(m.gauge("x").is_none());
        m.set_gauge("x", 1.0);
        m.set_gauge("x", 2.5);
        assert_eq!(m.gauge("x"), Some(2.5));
    }

    #[test]
    fn percentiles_nearest_rank() {
        // 1..=100: p50 = 50, p99 = 99, p100 = 100, p1 = 1.
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe_sample("lat", i as f64);
        }
        assert_eq!(m.percentile("lat", 50.0), Some(50.0));
        assert_eq!(m.percentile("lat", 99.0), Some(99.0));
        assert_eq!(m.percentile("lat", 100.0), Some(100.0));
        assert_eq!(m.percentile("lat", 1.0), Some(1.0));
        assert_eq!(m.percentile("lat", 0.0), Some(1.0));
        assert!(m.percentile("missing", 50.0).is_none());
    }

    #[test]
    fn percentile_single_sample() {
        let m = Metrics::new();
        m.observe_sample("one", 7.5);
        assert_eq!(m.percentile("one", 50.0), Some(7.5));
        assert_eq!(m.percentile("one", 99.0), Some(7.5));
    }

    #[test]
    fn sample_window_slides() {
        let mut w = SampleWindow::default();
        for i in 0..(SAMPLE_WINDOW + 100) {
            w.push(i as f64);
        }
        assert_eq!(w.total(), (SAMPLE_WINDOW + 100) as u64);
        // Oldest 100 samples evicted: the minimum retained value is >= 100.
        assert!(w.percentile(0.0).unwrap() >= 100.0);
    }

    #[test]
    fn poisoned_locks_do_not_sink_the_registry() {
        // Regression test: a worker panicking while holding a registry lock
        // used to poison it, cascading PoisonError panics through every
        // later incr/observe/report in the process. Deliberately poison
        // every inner mutex, then verify the registry still works.
        let m = Metrics::new();
        m.incr("jobs");
        m.observe("lat", 0.5);
        m.set_gauge("g", 1.0);
        m.observe_sample("s", 1.0);
        fn poison<T>(mutex: &Mutex<T>) {
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _guard = locked(mutex);
                panic!("worker dies holding the metrics lock");
            }));
            assert!(caught.is_err());
            assert!(mutex.lock().is_err(), "the mutex must actually be poisoned");
        }
        poison(&m.counters);
        poison(&m.latencies);
        poison(&m.gauges);
        poison(&m.samples);
        // Writes and reads still land after the poisoning.
        m.incr("jobs");
        assert_eq!(m.counter("jobs"), 2);
        m.observe("lat", 1.5);
        assert_eq!(m.latency("lat").unwrap().count(), 2);
        m.set_gauge("g", 2.0);
        assert_eq!(m.gauge("g"), Some(2.0));
        m.observe_sample("s", 3.0);
        assert_eq!(m.percentile("s", 100.0), Some(3.0));
        assert_eq!(m.counters_snapshot(), vec![("jobs".to_string(), 2)]);
        assert!(m.report().contains("counter jobs = 2"));
    }

    #[test]
    fn counters_snapshot_sorted() {
        let m = Metrics::new();
        m.incr("b.two");
        m.add("a.one", 3);
        assert_eq!(
            m.counters_snapshot(),
            vec![("a.one".to_string(), 3), ("b.two".to_string(), 1)]
        );
    }

    #[test]
    fn percentile_helpers() {
        assert_eq!(percentile_of_unsorted(&[], 50.0), None);
        assert_eq!(percentile_of_unsorted(&[3.0, 1.0, 2.0], 50.0), Some(2.0));
        assert_eq!(percentile_of_sorted(&[1.0, 2.0, 3.0], 100.0), 3.0);
        assert_eq!(percentile_of_sorted(&[42.0], 99.0), 42.0);
    }
}
