//! Central registry of every metric-series name the service emits.
//!
//! Production code must name a series through these constants (or the
//! dynamic-name helpers below) — never through an inline string literal.
//! `cargo xtask lint` enforces that rule across `rust/src`, which keeps
//! three vocabularies from drifting apart as the codebase grows:
//!
//! 1. the names emitted at runtime (this module),
//! 2. the Prometheus-sanitized forms scraped from `--metrics-addr`,
//! 3. the metric table documented in the README's Observability section.
//!
//! Kernel-phase series (`kernel.*` / `kernel.ext.*`) also live here so the
//! [`Phase`](crate::obs::event::Phase) enum, the trace span names, and the
//! bench phase tables all resolve through one definition.
//!
//! Adding a metric: add the constant (and a README table row), then use it.
//! The `ALL` table below is the linter's ground truth; a constant that is
//! not listed there fails the registry's own unit tests.

// --- job lifecycle -------------------------------------------------------

/// Jobs accepted by `submit_request`/`submit_batch_requests` (counter).
pub const JOBS_SUBMITTED: &str = "jobs.submitted";
/// Jobs that ran to completion (counter).
pub const JOBS_COMPLETED: &str = "jobs.completed";
/// Jobs whose output failed multiset validation (counter).
pub const JOBS_INVALID: &str = "jobs.invalid";
/// Jobs that panicked inside a worker and resolved `Err(WorkerLost)` (counter).
pub const JOBS_PANICKED: &str = "jobs.panicked";
/// Completed jobs by key dtype (counters).
pub const JOBS_DTYPE_I64: &str = "jobs.dtype.i64";
pub const JOBS_DTYPE_I32: &str = "jobs.dtype.i32";
pub const JOBS_DTYPE_U64: &str = "jobs.dtype.u64";
pub const JOBS_DTYPE_F64: &str = "jobs.dtype.f64";

// --- batch submission ----------------------------------------------------

/// Batches submitted (counter).
pub const BATCH_SUBMITTED: &str = "batch.submitted";
/// Batches fully waited/streamed to completion (counter).
pub const BATCH_COMPLETED: &str = "batch.completed";
/// Jobs submitted through the batch path (counter).
pub const BATCH_JOBS_SUBMITTED: &str = "batch.jobs.submitted";
/// Per-job latency sample window feeding batch p50/p99 (samples).
pub const BATCH_JOB_LATENCY: &str = "batch.job.latency";
/// Stats of the most recently completed batch (gauges).
pub const BATCH_LAST_P50_SECS: &str = "batch.last.p50_secs";
pub const BATCH_LAST_P99_SECS: &str = "batch.last.p99_secs";
pub const BATCH_LAST_JOBS_PER_SEC: &str = "batch.last.jobs_per_sec";

// --- parameter resolution ------------------------------------------------

/// Caller supplied explicit params — cache/model bypassed (counter).
pub const PARAMS_OVERRIDE: &str = "params.override";
/// Fingerprint class found in the tuning cache (counter).
pub const PARAMS_CACHE_HIT: &str = "params.cache_hit";
/// Fingerprint class missed the tuning cache (counter).
pub const PARAMS_CACHE_MISS: &str = "params.cache_miss";
/// Cache miss fell through to the symbolic model (counter).
pub const PARAMS_SYMBOLIC: &str = "params.symbolic";

// --- sort execution ------------------------------------------------------

/// End-to-end per-job sort latency (latency series).
pub const SORT_LATENCY: &str = "sort.latency";
/// Total elements sorted (counter).
pub const ELEMENTS_SORTED: &str = "elements.sorted";
/// Worker-scratch arena growth reallocations (counter).
pub const SCRATCH_GROWS: &str = "scratch.grows";

// --- online tuner --------------------------------------------------------

/// Tuner refinement cycles run (counter).
pub const TUNER_CYCLES: &str = "tuner.cycles";
/// GA generations executed across all cycles (counter).
pub const TUNER_GENERATIONS: &str = "tuner.generations";
/// Observations ingested from the service (counter).
pub const TUNER_OBSERVATIONS: &str = "tuner.observations";
/// Observations dropped by backpressure (counter).
pub const TUNER_DROPPED: &str = "tuner.dropped";
/// Improvements published to the tuning cache (counter).
pub const TUNER_PUBLISHES: &str = "tuner.publishes";
/// Publishes that updated external-sort (`:xm`) spill genes (counter).
pub const TUNER_EXT_PUBLISHES: &str = "tuner.ext_publishes";
/// Cycles that found no improvement worth publishing (counter).
pub const TUNER_NO_CHANGE: &str = "tuner.no_change";
/// Tracked classes evicted by the retention policy (counter).
pub const TUNER_EVICTED: &str = "tuner.evicted";
/// Fingerprint classes currently tracked (gauge).
pub const TUNER_CLASSES: &str = "tuner.classes";
/// Improvement percentage of the most recent publish (gauge).
pub const TUNER_LAST_IMPROVEMENT_PCT: &str = "tuner.last_improvement_pct";
/// params.cache_hit / (hit + miss) ratio (gauge).
pub const TUNER_CACHE_HIT_RATE: &str = "tuner.cache_hit_rate";

// --- tracing -------------------------------------------------------------

/// Trace events dropped at full rings, fleet-wide (counter).
pub const TRACE_DROPPED: &str = "trace.dropped";
/// Trace events ingested by the collector hub (counter).
pub const TRACE_INGESTED: &str = "trace.ingested";

// --- shard fleet ---------------------------------------------------------

/// Shard processes/connections that died (counter).
pub const SHARD_DEATHS: &str = "shard.deaths";
/// Dead local shards respawned (counter).
pub const SHARD_RESPAWNS: &str = "shard.respawns";
/// Jobs lost to a dying shard (counter).
pub const SHARD_JOBS_LOST: &str = "shard.jobs.lost";
/// Jobs refused because they exceed the frame size limit (counter).
pub const SHARD_JOBS_OVERSIZED: &str = "shard.jobs.oversized";
/// Tuning-cache publishes received from shards (counter).
pub const SHARD_CACHE_PUBLISHES: &str = "shard.cache.publishes";
/// Entries a shard absorbed from a router broadcast (counter).
pub const SHARD_CACHE_ABSORBED: &str = "shard.cache.absorbed";
/// Entries the router absorbed from shard publishes (counter).
pub const SHARD_CACHE_ENTRIES_ABSORBED: &str = "shard.cache.entries_absorbed";
/// Router-side merged tuning-cache size (gauge).
pub const SHARD_CACHE_ENTRIES: &str = "shard.cache.entries";
/// Cross-shard cache broadcasts sent (counter).
pub const SHARD_CACHE_BROADCASTS: &str = "shard.cache.broadcasts";
/// Remote-shard redial attempts (counter).
pub const SHARDS_REDIALS: &str = "shards.redials";
/// Jobs shed at the admission gate (`Err(Overloaded)`) (counter).
pub const SHARDS_SHED: &str = "shards.shed";
/// Router dispatch-queue depth (gauge).
pub const ROUTER_QUEUE_DEPTH: &str = "router.queue.depth";
/// Shard-local tuning-cache size, as reported in telemetry (counter key).
pub const CACHE_ENTRIES: &str = "cache.entries";

// --- out-of-core (external sort) -----------------------------------------

/// Jobs escalated to the external spill sorter (counter).
pub const EXTSORT_JOBS: &str = "extsort.jobs";
/// Sorted runs spilled to disk (counter).
pub const EXTSORT_RUNS_SPILLED: &str = "extsort.runs_spilled";
/// K-way merge passes executed (counter).
pub const EXTSORT_MERGE_PASSES: &str = "extsort.merge_passes";
/// Result chunks streamed to tickets (counter).
pub const EXTSORT_CHUNKS_STREAMED: &str = "extsort.chunks_streamed";
/// Peak working-set bytes of the most recent external job (gauge).
pub const EXTSORT_LAST_PEAK_BYTES: &str = "extsort.last_peak_bytes";
/// External jobs cancelled mid-stream (counter).
pub const EXTSORT_CANCELLED: &str = "extsort.cancelled";
/// External jobs that failed with an I/O or plan error (counter).
pub const EXTSORT_ERRORS: &str = "extsort.errors";

// --- kernel phases -------------------------------------------------------
//
// One name per `Phase` variant; `Phase::metric_name` resolves through these
// constants, and `cargo xtask lint` cross-checks this block against the
// `Phase` enum and the README phase list. Order matches `Phase::all()`.

pub const KERNEL_RADIX_MINMAX: &str = "kernel.radix.minmax";
pub const KERNEL_RADIX_COUNT: &str = "kernel.radix.count";
pub const KERNEL_RADIX_SCAN: &str = "kernel.radix.scan";
pub const KERNEL_RADIX_SCATTER: &str = "kernel.radix.scatter";
pub const KERNEL_RADIX_COPYBACK: &str = "kernel.radix.copyback";
pub const KERNEL_MERGE_RUN_SORT: &str = "kernel.merge.run_sort";
pub const KERNEL_MERGE_MERGE_LEVELS: &str = "kernel.merge.merge_levels";
pub const KERNEL_SAMPLE_SAMPLE: &str = "kernel.sample.sample";
pub const KERNEL_SAMPLE_PARTITION: &str = "kernel.sample.partition";
pub const KERNEL_SAMPLE_BUCKET_SORT: &str = "kernel.sample.bucket_sort";
pub const KERNEL_EXT_RUN_FORM: &str = "kernel.ext.run_form";
pub const KERNEL_EXT_SPILL: &str = "kernel.ext.spill";
pub const KERNEL_EXT_MERGE: &str = "kernel.ext.merge";

/// The kernel-phase names in [`Phase::all()`](crate::obs::event::Phase::all)
/// order. Indexed by `Phase::wire()`.
pub const KERNEL_PHASES: [&str; 13] = [
    KERNEL_RADIX_MINMAX,
    KERNEL_RADIX_COUNT,
    KERNEL_RADIX_SCAN,
    KERNEL_RADIX_SCATTER,
    KERNEL_RADIX_COPYBACK,
    KERNEL_MERGE_RUN_SORT,
    KERNEL_MERGE_MERGE_LEVELS,
    KERNEL_SAMPLE_SAMPLE,
    KERNEL_SAMPLE_PARTITION,
    KERNEL_SAMPLE_BUCKET_SORT,
    KERNEL_EXT_RUN_FORM,
    KERNEL_EXT_SPILL,
    KERNEL_EXT_MERGE,
];

// --- dynamic names -------------------------------------------------------
//
// Per-shard / per-client series names are minted through these helpers so
// the template lives here (and the linter can whitelist the helper call
// sites instead of chasing `format!` strings through the tree).

/// `shard.{idx}.jobs.completed` — jobs completed by one shard (counter).
pub fn shard_jobs_completed(idx: usize) -> String {
    format!("shard.{idx}.jobs.completed")
}

/// `shard.{idx}.jobs.routed` — jobs dispatched to one shard (counter).
pub fn shard_jobs_routed(idx: usize) -> String {
    format!("shard.{idx}.jobs.routed")
}

/// `shard.{idx}.local.{name}` — a shard-local counter re-exported by the
/// router from shard telemetry (gauge).
pub fn shard_local(idx: usize, name: &str) -> String {
    format!("shard.{idx}.local.{name}")
}

/// `shards.{name}` — a shard-local counter summed across the fleet (gauge).
pub fn shards_total(name: &str) -> String {
    format!("shards.{name}")
}

/// `client.{client}.dispatched` — per-client dispatch counter under the
/// round-robin fairness scheduler (counter).
pub fn client_dispatched(client: u64) -> String {
    format!("client.{client}.dispatched")
}

/// Every static series name in the registry except the kernel phases
/// (those live in [`KERNEL_PHASES`]). The linter and the registry's own
/// tests treat `ALL` + `KERNEL_PHASES` as the canonical vocabulary;
/// dynamic helper templates are represented by their `{}`-form
/// documentation strings in [`DYNAMIC`].
pub const ALL: [&str; 55] = [
    JOBS_SUBMITTED,
    JOBS_COMPLETED,
    JOBS_INVALID,
    JOBS_PANICKED,
    JOBS_DTYPE_I64,
    JOBS_DTYPE_I32,
    JOBS_DTYPE_U64,
    JOBS_DTYPE_F64,
    BATCH_SUBMITTED,
    BATCH_COMPLETED,
    BATCH_JOBS_SUBMITTED,
    BATCH_JOB_LATENCY,
    BATCH_LAST_P50_SECS,
    BATCH_LAST_P99_SECS,
    BATCH_LAST_JOBS_PER_SEC,
    PARAMS_OVERRIDE,
    PARAMS_CACHE_HIT,
    PARAMS_CACHE_MISS,
    PARAMS_SYMBOLIC,
    SORT_LATENCY,
    ELEMENTS_SORTED,
    SCRATCH_GROWS,
    TUNER_CYCLES,
    TUNER_GENERATIONS,
    TUNER_OBSERVATIONS,
    TUNER_DROPPED,
    TUNER_PUBLISHES,
    TUNER_EXT_PUBLISHES,
    TUNER_NO_CHANGE,
    TUNER_EVICTED,
    TUNER_CLASSES,
    TUNER_LAST_IMPROVEMENT_PCT,
    TUNER_CACHE_HIT_RATE,
    TRACE_DROPPED,
    TRACE_INGESTED,
    SHARD_DEATHS,
    SHARD_RESPAWNS,
    SHARD_JOBS_LOST,
    SHARD_JOBS_OVERSIZED,
    SHARD_CACHE_PUBLISHES,
    SHARD_CACHE_ABSORBED,
    SHARD_CACHE_ENTRIES_ABSORBED,
    SHARD_CACHE_ENTRIES,
    SHARD_CACHE_BROADCASTS,
    SHARDS_REDIALS,
    SHARDS_SHED,
    ROUTER_QUEUE_DEPTH,
    CACHE_ENTRIES,
    EXTSORT_JOBS,
    EXTSORT_RUNS_SPILLED,
    EXTSORT_MERGE_PASSES,
    EXTSORT_CHUNKS_STREAMED,
    EXTSORT_LAST_PEAK_BYTES,
    EXTSORT_CANCELLED,
    EXTSORT_ERRORS,
];

/// Documentation templates for the dynamic helpers above (`{}` marks the
/// interpolated part). The linter uses these to match README rows.
pub const DYNAMIC: [&str; 5] = [
    "shard.{idx}.jobs.completed",
    "shard.{idx}.jobs.routed",
    "shard.{idx}.local.{name}",
    "shards.{name}",
    "client.{client}.dispatched",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::Phase;

    #[test]
    fn names_are_unique_and_well_formed() {
        let mut seen = std::collections::HashSet::new();
        for name in ALL.iter().chain(KERNEL_PHASES.iter()) {
            assert!(seen.insert(*name), "duplicate metric name {name}");
            assert!(
                name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || "._".contains(c)),
                "bad metric name {name:?}"
            );
            assert!(!name.starts_with('.') && !name.ends_with('.'), "bad name {name:?}");
        }
    }

    #[test]
    fn prometheus_sanitized_forms_stay_unique() {
        let sanitize = |n: &str| {
            let body: String =
                n.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect();
            format!("evosort_{body}")
        };
        let mut seen = std::collections::HashSet::new();
        for name in ALL.iter().chain(KERNEL_PHASES.iter()) {
            assert!(seen.insert(sanitize(name)), "prometheus collision for {name}");
        }
    }

    #[test]
    fn kernel_phase_table_matches_phase_enum() {
        assert_eq!(KERNEL_PHASES.len(), Phase::COUNT);
        for phase in Phase::all() {
            assert_eq!(KERNEL_PHASES[phase.wire() as usize], phase.metric_name());
        }
    }

    #[test]
    fn dynamic_helpers_match_their_templates() {
        assert_eq!(shard_jobs_completed(3), "shard.3.jobs.completed");
        assert_eq!(shard_jobs_routed(0), "shard.0.jobs.routed");
        assert_eq!(shard_local(1, "jobs"), "shard.1.local.jobs");
        assert_eq!(shards_total("jobs"), "shards.jobs");
        assert_eq!(client_dispatched(7), "client.7.dispatched");
    }
}
