//! # EvoSort
//!
//! A reproduction of *"EvoSort: A Genetic-Algorithm-Based Adaptive Parallel
//! Sorting Framework for Large-Scale High Performance Computing"* (Raj & Deb,
//! 2025) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Rust (this crate)** — the adaptive sorting framework: refined parallel
//!   mergesort, block-based LSD radix sort, the GA auto-tuner, the
//!   symbolic-regression performance model, and the coordination layer
//!   (sort service, tuning cache, master pipeline, CLI, benches).
//! * **JAX / Pallas (build time)** — the bitonic tile-sort and radix
//!   histogram kernels, AOT-lowered to HLO text in `artifacts/`.
//! * **PJRT runtime bridge** — [`runtime`] loads those artifacts and exposes
//!   them as a [`sort::TileSorter`] backend selectable by the adaptive
//!   dispatcher (`A_code = 5`).
//!
//! ## Quickstart
//!
//! ```no_run
//! use evosort::prelude::*;
//!
//! let mut data = evosort::data::generate_i64(1_000_000, Distribution::Uniform, 42, 8);
//! let sorter = AdaptiveSorter::new(8);
//! let params = SortParams::paper_1e7(); // or GaDriver::run(...) to tune
//! sorter.sort_i64(&mut data, &params);
//! assert!(data.windows(2).all(|w| w[0] <= w[1]));
//! ```

// Style lints that fight the hand-rolled kernel code (index-heavy scatter
// loops, explicit range guards, fat tuple returns for merge-path jobs). CI
// denies warnings, so the exceptions are spelled out once, here.
#![allow(
    clippy::manual_range_contains,
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity
)]

pub mod autotune;
pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod extsort;
pub mod ga;
pub mod obs;
pub mod params;
pub mod rng;
pub mod runtime;
pub mod sort;
pub mod symbolic;
pub mod testkit;
pub mod util;

/// Common imports for library users.
pub mod prelude {
    pub use crate::autotune::{AutotunePolicy, Fingerprint};
    pub use crate::coordinator::{ServiceConfig, SortRequest, SortService, Ticket};
    pub use crate::data::Distribution;
    pub use crate::exec::{ExecMode, Executor};
    pub use crate::params::{ACode, Bounds, SortParams};
    pub use crate::sort::{AdaptiveSorter, Baseline, Dtype, MergeTuning, SortKey, SortPayload};
}
