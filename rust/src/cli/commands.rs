//! CLI command implementations. Each maps one subcommand onto the library.

use anyhow::{bail, Result};

use crate::bench_harness::json::{self as bench_json, BenchDoc, BenchEntry};
use crate::bench_harness::{measure, scale_div, scaled_size, BenchConfig, Table};
use crate::coordinator::{ParamSource, PipelineConfig, ServiceConfig, SortRequest, SortService};
use crate::coordinator::metrics::names;
use crate::data::{self, Distribution};
use crate::ga::{GaConfig, GaDriver};
use crate::params::{ACode, RadixWidth, SortParams};
use crate::runtime::{Manifest, XlaTileSorter};
use crate::sort::{AdaptiveSorter, Baseline, Dtype, SortPayload};
use crate::symbolic::SymbolicModel;
use crate::util::{default_threads, fmt_count, fmt_secs, timer};

use super::Args;

fn dist_of(args: &Args) -> Result<Distribution> {
    let name = args.str_or("dist", "uniform");
    Distribution::parse(name).ok_or_else(|| anyhow::anyhow!("unknown distribution {name:?}"))
}

fn dtype_of_name(name: &str) -> Result<Dtype> {
    Dtype::parse(name).ok_or_else(|| anyhow::anyhow!("unknown dtype {name:?} (i64|i32|u64|f64)"))
}

/// Override an [`AutotunePolicy`](crate::autotune::AutotunePolicy)'s knobs
/// from CLI flags, falling back to
/// `base` for anything not given. Every path that builds a policy from
/// flags goes through here — single-process `serve --autotune`, the
/// sharded router, and the `shard-worker` child — so a knob added to one
/// path cannot silently diverge from the others (only the `base` defaults
/// intentionally differ per path).
fn autotune_policy_from(
    args: &Args,
    base: crate::autotune::AutotunePolicy,
) -> Result<crate::autotune::AutotunePolicy> {
    let persist_path = args
        .get("cache-file")
        .map(std::path::PathBuf::from)
        .or_else(|| base.persist_path.clone());
    Ok(crate::autotune::AutotunePolicy {
        min_observations: args.u64_or("min-obs", base.min_observations)?,
        cooldown_observations: args.u64_or("cooldown", base.cooldown_observations)?,
        retained_sample_cap: args.usize_or("sample-cap", base.retained_sample_cap)?,
        generations_per_cycle: args.usize_or("tuner-generations", base.generations_per_cycle)?,
        population: args.usize_or("tuner-population", base.population)?,
        max_cpu_share: args.f64_or("cpu-share", base.max_cpu_share)?,
        min_improvement_pct: args.f64_or("min-improvement", base.min_improvement_pct)?,
        sample_every: args.u64_or("sample-every", base.sample_every)?,
        persist_path,
        ..base
    })
}

/// The observation-eager base the `serve` demo/smoke paths start from.
/// Production defaults stay for the noise margin (`min_improvement_pct`)
/// and the sampling/budget knobs — the CLI must not silently inherit the
/// test-only 0% margin of `AutotunePolicy::quick()`, which would let
/// timing noise churn (and persist) the cache; the CI smokes pass
/// `--min-improvement 0` explicitly.
fn demo_autotune_base() -> crate::autotune::AutotunePolicy {
    crate::autotune::AutotunePolicy {
        min_observations: 8,
        cooldown_observations: 2,
        population: 8,
        max_cpu_share: 0.5,
        ..crate::autotune::AutotunePolicy::default()
    }
}

fn dtype_of(args: &Args) -> Result<Dtype> {
    dtype_of_name(args.str_or("dtype", "i64"))
}

/// `serve` turns tracing on for `--trace` or any `--trace-log FILE`.
fn trace_wanted(args: &Args) -> bool {
    args.has("trace") || args.get("trace-log").is_some()
}

/// Spawn the Prometheus scrape endpoint when `--metrics-addr` was given.
/// The returned handle keeps the listener alive for the whole run.
fn spawn_metrics_server(
    args: &Args,
    metrics: &std::sync::Arc<crate::coordinator::metrics::Metrics>,
) -> Result<Option<crate::obs::MetricsServer>> {
    let Some(addr) = args.get("metrics-addr") else { return Ok(None) };
    let server = crate::obs::MetricsServer::spawn(addr, std::sync::Arc::clone(metrics))?;
    println!("metrics scrape endpoint: http://{}/metrics", server.addr());
    Ok(Some(server))
}

/// Scrape our own `--metrics-addr` endpoint once and verify it serves
/// `evosort_*` series — the smoke proves the whole export path (registry →
/// Prometheus text → HTTP) without needing curl choreography in CI.
fn self_scrape(server: &crate::obs::MetricsServer) -> Result<()> {
    use std::io::{Read as _, Write as _};
    let mut stream = std::net::TcpStream::connect(server.addr())?;
    stream.write_all(b"GET /metrics HTTP/1.1\r\nHost: evosort\r\nConnection: close\r\n\r\n")?;
    let mut response = String::new();
    stream.read_to_string(&mut response)?;
    anyhow::ensure!(
        response.starts_with("HTTP/1.1 200"),
        "metrics scrape returned {:?}",
        response.lines().next().unwrap_or("")
    );
    let series = response.lines().filter(|l| l.starts_with("evosort_")).count();
    println!("self-scrape: {series} evosort_* series served");
    anyhow::ensure!(series > 0, "metrics scrape served no evosort_* series");
    Ok(())
}

/// End-of-run trace report for a `serve` path that had a
/// [`TraceHub`](crate::obs::TraceHub): wait briefly for in-flight shard
/// batches to land, flush the JSONL sink, print a one-line summary, and —
/// when `strict` — fail on incomplete span chains (a submitted job without
/// exactly one terminal event is a tracing bug, not noise). A
/// `--chaos-kill` run is not strict: a SIGKILLed worker legitimately
/// strands its own stream's terminal (the router-side `worker_lost`
/// terminal still closes the trace).
fn finish_trace(hub: &crate::obs::TraceHub, trace_log: Option<&str>, strict: bool) -> Result<()> {
    use std::time::{Duration, Instant};
    // Worker shards stream their rings on the telemetry tick; give the last
    // batch a moment to arrive instead of snapshotting a torn timeline.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        hub.flush();
        let problems = crate::obs::report::check(&hub.snapshot());
        if problems.is_empty() || Instant::now() >= deadline {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    let events = hub.snapshot();
    let problems = crate::obs::report::check(&events);
    println!(
        "trace: {} events across {} traces ({} dropped)",
        events.len(),
        hub.timeline_len(),
        hub.dropped()
    );
    if let Some(path) = trace_log {
        println!("trace log written to {path} (inspect with `evosort trace {path}`)");
    }
    for p in &problems {
        println!("  trace problem: {p}");
    }
    if strict {
        anyhow::ensure!(problems.is_empty(), "{} incomplete span chains", problems.len());
    }
    Ok(())
}

fn threads_of(args: &Args) -> Result<usize> {
    args.usize_or("threads", default_threads())
}

/// Parse `--memory-budget <bytes>` / `--spill-dir <path>` into the
/// out-of-core escalation config. No budget (the default) keeps every job
/// in RAM — the historical behaviour.
fn external_config_of(args: &Args) -> Result<Option<crate::extsort::ExternalConfig>> {
    let budget = args.usize_or("memory-budget", 0)?;
    if budget == 0 {
        anyhow::ensure!(
            args.get("spill-dir").is_none(),
            "--spill-dir requires --memory-budget <bytes>"
        );
        return Ok(None);
    }
    let mut config = crate::extsort::ExternalConfig::new(budget);
    if let Some(dir) = args.get("spill-dir") {
        config = config.with_spill_dir(std::path::PathBuf::from(dir));
    }
    Ok(Some(config))
}

/// Post-run assertions for a `serve --memory-budget` run (the CI spill
/// smoke): at least one run actually spilled, and — when the user pointed
/// us at a dedicated `--spill-dir` — the root holds no leftover per-job
/// spill directories.
fn check_spill_smoke(svc: &SortService, spill_dir: Option<&std::path::Path>) -> Result<()> {
    let escalated = svc.metrics().counter(names::EXTSORT_JOBS);
    let spilled = svc.metrics().counter(names::EXTSORT_RUNS_SPILLED);
    println!(
        "out-of-core: {escalated} jobs escalated, {spilled} runs spilled, \
         last peak working set {:.0} bytes",
        svc.metrics().gauge(names::EXTSORT_LAST_PEAK_BYTES).unwrap_or(0.0)
    );
    anyhow::ensure!(
        spilled > 0,
        "--memory-budget given but nothing spilled; raise --n or lower the budget"
    );
    if let Some(dir) = spill_dir {
        let leftover = std::fs::read_dir(dir).map(|it| it.count()).unwrap_or(0);
        anyhow::ensure!(
            leftover == 0,
            "{leftover} spill entries left under {} after the run",
            dir.display()
        );
    }
    Ok(())
}

/// `--sort-threads` / `--queue-capacity` for the serve paths, defaulting to
/// the thread budget split across workers and the stock queue depth (the
/// same defaults as the `[service]` config keys).
fn serve_sizing(args: &Args, workers: usize, threads: usize) -> Result<(usize, usize)> {
    let sort_threads = args.usize_or("sort-threads", (threads / workers.max(1)).max(1))?;
    let queue_capacity = args.usize_or("queue-capacity", 64)?;
    Ok((sort_threads.max(1), queue_capacity.max(1)))
}

/// Parse `--exec parked|spawn` (the kernel execution backend; defaults to
/// the persistent parked executor).
fn exec_mode_of(args: &Args) -> Result<crate::exec::ExecMode> {
    let name = args.str_or("exec", "parked");
    crate::exec::ExecMode::parse(name)
        .ok_or_else(|| anyhow::anyhow!("unknown exec mode {name:?} (parked|spawn)"))
}

/// Try to attach the XLA tile backend; warn-and-continue when artifacts are
/// absent (the dispatcher falls back to merge for A_code=5).
fn sorter_with_optional_xla(threads: usize, want_xla: bool) -> AdaptiveSorter {
    let sorter = AdaptiveSorter::new(threads);
    if !want_xla {
        return sorter;
    }
    match XlaTileSorter::from_default_artifacts() {
        Ok(backend) => sorter.with_xla(std::sync::Arc::new(backend)),
        Err(e) => {
            crate::log_warn!("XLA backend unavailable ({e}); falling back to merge");
            sorter
        }
    }
}

/// `evosort sort` — generate, sort, validate, report.
pub fn cmd_sort(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 10_000_000)?;
    let seed = args.u64_or("seed", 42)?;
    let threads = threads_of(args)?;
    let dist = dist_of(args)?;
    let algo = args.str_or("algo", "auto");

    println!("generating {} {} i64 elements (seed {seed})", fmt_count(n), dist.name());
    let mut array = data::generate_i64(n, dist, seed, threads);
    let fp = data::validate::fingerprint_i64(&array, threads);

    // Baseline algos run directly; EvoSort paths resolve parameters.
    let secs = match algo {
        "baseline-quicksort" | "baseline-mergesort" | "std" => {
            let b = match algo {
                "baseline-quicksort" => Baseline::Quicksort,
                "baseline-mergesort" => Baseline::Mergesort,
                _ => Baseline::Std,
            };
            let (_, secs) = timer::time(|| b.sort_i64(&mut array));
            println!("{}: {}", b.name(), fmt_secs(secs));
            secs
        }
        _ => {
            let params = resolve_params(args, n, dist, threads)?;
            let sorter = sorter_with_optional_xla(threads, params.algorithm == ACode::XlaTile);
            println!("params: {params}");
            let (_, secs) = timer::time(|| sorter.sort_i64(&mut array, &params));
            println!("evosort: {} ({:.1} Melem/s)", fmt_secs(secs), n as f64 / secs / 1e6);
            secs
        }
    };

    let verdict = data::validate::validate_i64(fp, &array, threads);
    println!("validation: {verdict:?}  throughput {:.2} Melem/s", n as f64 / secs / 1e6);
    if verdict != data::validate::Verdict::Valid {
        bail!("output failed validation");
    }
    Ok(())
}

fn resolve_params(args: &Args, n: usize, dist: Distribution, threads: usize) -> Result<SortParams> {
    if args.has("tune") {
        let cfg = ga_config_from(args)?;
        let driver = GaDriver::new(cfg);
        let sample_cap = args.usize_or("sample-cap", 4_000_000)?;
        let r = driver.run_for_size(n, sample_cap, dist, AdaptiveSorter::new(threads));
        println!("GA tuned ({} evals): {}", r.evaluations, r.best);
        return Ok(r.best);
    }
    if args.has("symbolic") {
        return Ok(SymbolicModel::paper().params_for(n));
    }
    Ok(match args.str_or("algo", "auto") {
        "auto" => SymbolicModel::paper().params_for(n),
        "merge" => SortParams { algorithm: ACode::Merge, ..SymbolicModel::paper().params_for(n) },
        "radix" => SortParams { algorithm: ACode::Radix, ..SymbolicModel::paper().params_for(n) },
        "xla" => SortParams { algorithm: ACode::XlaTile, ..SymbolicModel::paper().params_for(n) },
        other => bail!("unknown --algo {other:?}"),
    })
}

fn ga_config_from(args: &Args) -> Result<GaConfig> {
    Ok(GaConfig {
        population: args.usize_or("population", 30)?,
        generations: args.usize_or("generations", 10)?,
        seed: args.u64_or("seed", 42)?,
        crossover_prob: args.f64_or("crossover", 0.7)?,
        mutation_prob: args.f64_or("mutation", 0.3)?,
        ..GaConfig::default()
    })
}

/// `evosort tune` — GA convergence table (the Figures 2–6 series).
pub fn cmd_tune(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 10_000_000)?;
    let threads = threads_of(args)?;
    let dist = dist_of(args)?;
    let sample_cap = args.usize_or("sample-cap", 4_000_000)?;
    let cfg = ga_config_from(args)?;
    println!(
        "GA tuning for n={} (sample {}), pop={}, {} generations",
        fmt_count(n),
        fmt_count(n.min(sample_cap)),
        cfg.population,
        cfg.generations
    );
    let driver = GaDriver::new(cfg);
    let result = driver.run_for_size(n, sample_cap, dist, AdaptiveSorter::new(threads));

    let mut table = Table::new(&["gen", "best(s)", "avg(s)", "worst(s)", "best genome"]);
    for h in &result.history {
        table.row(&[
            h.generation.to_string(),
            format!("{:.4}", h.best),
            format!("{:.4}", h.average),
            format!("{:.4}", h.worst),
            format!("{:?}", h.best_genome),
        ]);
    }
    table.print();
    println!("best individual: {}  ({} timed evals)", result.best, result.evaluations);
    Ok(())
}

/// `evosort pipeline` — Algorithm 1 across sizes, Table-1-shaped output.
/// With `--config file.toml`, all settings come from the config system.
pub fn cmd_pipeline(args: &Args) -> Result<()> {
    if let Some(path) = args.get("config") {
        let rc = crate::config::run::RunConfig::load(std::path::Path::new(path))?;
        crate::log_info!("loaded config from {path} ({} sizes)", rc.pipeline.sizes.len());
        let rows = crate::coordinator::pipeline::run(&rc.pipeline);
        print_pipeline_rows(&rows);
        return Ok(());
    }
    let sizes = args.sizes_or("sizes", &[1_000_000, 10_000_000])?;
    let threads = threads_of(args)?;
    let dist = dist_of(args)?;
    let params = if args.has("symbolic") {
        ParamSource::Symbolic(SymbolicModel::paper())
    } else if args.has("fixed") {
        ParamSource::Fixed(SortParams::paper_1e7())
    } else {
        ParamSource::Ga(GaConfig {
            population: args.usize_or("population", 12)?,
            generations: args.usize_or("generations", 6)?,
            seed: args.u64_or("seed", 42)?,
            ..GaConfig::default()
        })
    };
    let config = PipelineConfig {
        sizes,
        dist,
        seed: args.u64_or("seed", 42)?,
        threads,
        params,
        sample_cap: args.usize_or("sample-cap", 4_000_000)?,
        baselines: vec![Baseline::Quicksort, Baseline::Mergesort, Baseline::Std],
    };
    let rows = crate::coordinator::pipeline::run(&config);
    print_pipeline_rows(&rows);
    Ok(())
}

fn print_pipeline_rows(rows: &[crate::coordinator::PipelineRow]) {
    let mut table = Table::new(&["n", "evosort", "quicksort", "mergesort", "std", "speedup", "valid"]);
    for r in rows {
        let find = |b: Baseline| {
            r.baselines
                .iter()
                .find(|(bb, _, _)| *bb == b)
                .map(|(_, t, _)| fmt_secs(*t))
                .unwrap_or_else(|| "-".into())
        };
        table.row(&[
            fmt_count(r.n),
            fmt_secs(r.evosort_secs),
            find(Baseline::Quicksort),
            find(Baseline::Mergesort),
            find(Baseline::Std),
            format!("{:.1}x", r.best_speedup()),
            r.validated.to_string(),
        ]);
    }
    table.print();
}

/// `evosort symbolic` — §7: print closed-form params, optionally fit from a
/// fresh GA sweep (Figures 7–11 data).
pub fn cmd_symbolic(args: &Args) -> Result<()> {
    let model = if let Some(_sweep) = args.get("sweep") {
        let sizes = args.sizes_or("sweep", &[])?;
        let threads = threads_of(args)?;
        let dist = dist_of(args)?;
        println!("running GA sweep over {} sizes to fit quadratics...", sizes.len());
        let mut points = Vec::new();
        for &n in &sizes {
            let cfg = GaConfig {
                population: args.usize_or("population", 10)?,
                generations: args.usize_or("generations", 5)?,
                seed: args.u64_or("seed", 42)? ^ n as u64,
                ..GaConfig::default()
            };
            let r = GaDriver::new(cfg).run_for_size(
                n,
                args.usize_or("sample-cap", 2_000_000)?,
                dist,
                AdaptiveSorter::new(threads),
            );
            println!("  n={}: {}", fmt_count(n), r.best);
            points.push((n, r.best));
        }
        SymbolicModel::fit(&points)
            .ok_or_else(|| anyhow::anyhow!("sweep too small to fit (need >= 3 sizes)"))?
    } else {
        SymbolicModel::paper()
    };

    println!("\nquadratic models T(x) = a·x² + b·x + c, x = log10 n:");
    let mut table = Table::new(&["threshold", "a", "b", "c", "vertex x*", "n*", "shape"]);
    for (name, q) in [
        ("T_insertion", model.insertion),
        ("T_par_merge", model.parallel_merge),
        ("T_fallback", model.fallback),
        ("T_tile", model.tile),
    ] {
        table.row(&[
            name.to_string(),
            format!("{:.2}", q.a),
            format!("{:.2}", q.b),
            format!("{:.2}", q.c),
            format!("{:.2}", q.vertex_x()),
            format!("{:.2e}", q.vertex_n()),
            if q.is_convex() { "convex (min)".into() } else { "concave (max)".into() },
        ]);
    }
    table.print();

    let n = args.usize_or("n", 100_000_000)?;
    println!("params_for({}) = {}", fmt_count(n), model.params_for(n));
    Ok(())
}

/// `evosort repro` — regenerate a paper table at testbed scale.
pub fn cmd_repro(args: &Args) -> Result<()> {
    let table_no = args.usize_or("table", 1)?;
    if let Some(div) = args.get("scale-div") {
        std::env::set_var("EVOSORT_BENCH_SCALE_DIV", div);
    }
    match table_no {
        1 => crate::bench_harness::tables::print_table1(threads_of(args)?),
        2 => crate::bench_harness::tables::print_table2(threads_of(args)?),
        other => bail!("unknown table {other} (1 or 2)"),
    }
    Ok(())
}

/// `evosort serve` — run the sort service demo. `--dtype i64|i32|u64|f64`
/// selects the key dtype (floats sort in `total_cmp` order). With `--batch`,
/// jobs go through the batched submission path (shared work queue, per-shard
/// scratch reuse) and the p50/p99/jobs-per-sec report is printed. With
/// `--autotune`, the service owns an online tuner: repeated batches of one
/// workload shape are submitted and the background GA refines the
/// dtype-tagged fingerprint-keyed cache while traffic flows. With
/// `--shards N` (N ≥ 2) or `--connect <endpoints>`, the service runs
/// cross-process: a router spawns N `shard-worker` child processes (over
/// Unix sockets, or TCP with `--transport tcp` / `--listen tcp://…`) and/or
/// dials externally started `shard-worker --listen` workers, then routes
/// mixed-dtype batches across the fleet; combined with `--autotune`, each
/// shard tunes locally and the run fails unless every shard served jobs and
/// at least one cross-shard cache broadcast occurred (the CI sharded
/// smoke). `--chaos-kill` additionally kills shard 0 mid-batch and fails
/// unless the batch still completes and the shard is redialed (the CI
/// failover smoke).
pub fn cmd_serve(args: &Args) -> Result<()> {
    let jobs = args.usize_or("jobs", 16)?;
    let n = args.usize_or("n", 1_000_000)?;
    let workers = args.usize_or("workers", 2)?;
    let threads = threads_of(args)?;
    let dtype = dtype_of(args)?;
    let shards = args.usize_or("shards", 1)?;
    if shards > 1 || args.get("connect").is_some() {
        return serve_sharded(args, jobs, n, workers, threads, shards);
    }
    if args.has("autotune") {
        return serve_autotune(args, jobs, n, workers, threads, dtype);
    }
    let traced = trace_wanted(args);
    let tracer = if traced {
        crate::obs::Tracer::enabled(crate::obs::DEFAULT_RING_CAPACITY, 0)
    } else {
        crate::obs::Tracer::disabled()
    };
    let external = external_config_of(args)?;
    let escalating = external.is_some();
    let spill_check = args.get("spill-dir").map(std::path::PathBuf::from);
    let (sort_threads, queue_capacity) = serve_sizing(args, workers, threads)?;
    let svc = SortService::new_traced(
        ServiceConfig::sized(workers, sort_threads, queue_capacity)
            .with_exec(exec_mode_of(args)?),
        tracer.clone(),
    );
    let hub = if traced {
        let path = args.get("trace-log").map(std::path::PathBuf::from);
        Some(crate::obs::TraceHub::new(
            tracer,
            path.as_deref(),
            Some(std::sync::Arc::clone(svc.metrics())),
        )?)
    } else {
        None
    };
    let scrape = spawn_metrics_server(args, svc.metrics())?;
    if args.has("batch") {
        let workload = crate::coordinator::BatchWorkload {
            jobs,
            sizes: vec![n, n / 4, n / 16, 1.max(n / 64), 0, 1],
            seed: args.u64_or("seed", 42)?,
            dtype,
            ..Default::default()
        };
        println!(
            "batched service: {workers} workers, one batch of {jobs} mixed {dtype} jobs \
             (max {} elements)",
            fmt_count(n)
        );
        let report = workload.run(&svc, threads);
        println!("{}", crate::coordinator::pipeline::batch_summary_line(&report));
        println!("\nmetrics:\n{}", svc.metrics().report());
        anyhow::ensure!(report.stats.invalid == 0, "{} jobs failed validation", report.stats.invalid);
        anyhow::ensure!(report.stats.failed == 0, "{} jobs failed to execute", report.stats.failed);
        if escalating {
            check_spill_smoke(&svc, spill_check.as_deref())?;
        }
        if let Some(hub) = &hub {
            finish_trace(hub, args.get("trace-log"), true)?;
        }
        if let Some(server) = &scrape {
            self_scrape(server)?;
        }
        return Ok(());
    }
    println!("service: {workers} workers, {jobs} {dtype} jobs of {} elements", fmt_count(n));
    let dists = ["uniform", "zipf", "gaussian", "nearly-sorted"];
    let tickets: Vec<_> = (0..jobs)
        .map(|i| {
            let dist_name = dists[i % dists.len()];
            let dist = Distribution::parse(dist_name).unwrap();
            let data = data::generate_i64(n, dist, i as u64, threads);
            let payload = SortPayload::from_i64_values(data, dtype);
            svc.submit_request(SortRequest::from_payload(payload).with_dist(dist_name))
        })
        .collect();
    for t in tickets {
        let out = t.wait().map_err(|e| anyhow::anyhow!("job lost: {e}"))?;
        println!(
            "job {:>3}: {} {} in {}  valid={}  params={}",
            out.id,
            fmt_count(out.len()),
            out.dtype(),
            fmt_secs(out.secs),
            out.valid,
            out.params
        );
        anyhow::ensure!(out.valid, "job {} failed validation", out.id);
    }
    println!("\nmetrics:\n{}", svc.metrics().report());
    if escalating {
        check_spill_smoke(&svc, spill_check.as_deref())?;
    }
    if let Some(hub) = &hub {
        finish_trace(hub, args.get("trace-log"), true)?;
    }
    if let Some(server) = &scrape {
        self_scrape(server)?;
    }
    Ok(())
}

/// `evosort serve --shards N` — the cross-process deployment demo/smoke.
///
/// Spawns a [`ShardedService`](crate::coordinator::ShardedService) (router +
/// N `shard-worker` child processes) and pushes rounds of mixed-dtype
/// batches through it. Exits non-zero unless every shard completed jobs;
/// with `--autotune`, additionally requires at least one cross-shard tuning
/// cache broadcast (a class tuned on one shard reached the others) — CI
/// uses that combination as the sharded smoke test.
#[cfg(unix)]
fn serve_sharded(
    args: &Args,
    jobs: usize,
    n: usize,
    workers: usize,
    threads: usize,
    shards: usize,
) -> Result<()> {
    use crate::autotune::AutotunePolicy;
    use crate::coordinator::{Endpoint, ShardedService, TransportKind};

    // Same flag set as `serve --autotune`, forwarded to every shard. The
    // persist path is intentionally stripped (shards sharing one file would
    // race; the router's merged cache is the service-level view).
    let autotune = if args.has("autotune") {
        let policy = autotune_policy_from(args, demo_autotune_base())?;
        Some(AutotunePolicy { persist_path: None, ..policy })
    } else {
        None
    };
    let autotuned = autotune.is_some();
    let per_shard_default = (threads / (workers * shards.max(1)).max(1)).max(1);
    let mut builder = ShardedService::builder()
        .shards(shards)
        .workers_per_shard(workers)
        .sort_threads(args.usize_or("sort-threads", per_shard_default)?)
        .queue_capacity(args.usize_or("queue-capacity", 64)?)
        .exec(exec_mode_of(args)?);
    if let Some(policy) = autotune {
        builder = builder.autotune(policy);
    }
    if let Some(name) = args.get("transport") {
        let Some(t) = TransportKind::parse(name) else {
            bail!("unknown --transport {name:?} (unix|tcp)");
        };
        builder = builder.transport(t);
    }
    if let Some(text) = args.get("listen") {
        builder = builder.endpoint(text.parse::<Endpoint>()?);
    }
    if let Some(list) = args.get("connect") {
        for part in list.split(',') {
            let part = part.trim();
            if !part.is_empty() {
                builder = builder.connect(part.parse::<Endpoint>()?);
            }
        }
    }
    if let Some(path) = args.get("trace-log") {
        builder = builder.trace_log(path.into());
    } else if args.has("trace") {
        builder = builder.trace(true);
    }
    let spec = builder.build();
    let transport = spec.transport;
    let remotes = spec.remotes.len();
    let fleet = spec.shards + remotes;
    let svc = ShardedService::spawn(spec)?;
    let scrape = spawn_metrics_server(args, svc.metrics())?;
    let rounds = args.usize_or("rounds", if autotuned { 40 } else { 1 })?;
    let seed = args.u64_or("seed", 42)?;
    // An explicit --dtype pins every job to that dtype (matching the
    // single-process serve paths); the default is a mixed-dtype cycle.
    let forced_dtype = args.get("dtype").map(dtype_of_name).transpose()?;
    let dtype_label =
        forced_dtype.map(|d| d.name().to_string()).unwrap_or_else(|| "mixed-dtype".into());
    println!(
        "sharded service: {shards} local shard processes x {workers} workers + {remotes} \
         remote workers over {transport}, up to {rounds} rounds of {jobs} {dtype_label} \
         jobs of {} elements",
        fmt_count(n)
    );
    let dtypes = Dtype::all();
    let make_requests = |round: usize| -> Vec<SortRequest> {
        (0..jobs)
            .map(|i| {
                let dtype = forced_dtype.unwrap_or(dtypes[i % dtypes.len()]);
                let job_seed = seed ^ (round * jobs + i) as u64;
                let data = data::generate_i64(n, Distribution::Uniform, job_seed, threads);
                SortRequest::from_payload(SortPayload::from_i64_values(data, dtype))
            })
            .collect()
    };
    if args.has("chaos-kill") {
        serve_chaos_round(&svc, make_requests(usize::MAX / 2), jobs)?;
    }
    for round in 0..rounds {
        let report = svc.submit_batch_requests(make_requests(round)).wait();
        anyhow::ensure!(report.stats.invalid == 0, "{} jobs invalid", report.stats.invalid);
        anyhow::ensure!(report.stats.failed == 0, "{} jobs failed", report.stats.failed);
        println!(
            "round {:>2}: {}",
            round + 1,
            crate::coordinator::pipeline::batch_summary_line(&report)
        );
        let metrics = svc.metrics();
        let all_active =
            (0..fleet).all(|s| metrics.counter(&names::shard_jobs_completed(s)) > 0);
        if all_active && (!autotuned || metrics.counter(names::SHARD_CACHE_BROADCASTS) > 0) {
            break;
        }
    }
    if autotuned {
        // Grace period: in-flight tuner cycles publish asynchronously; the
        // first publication triggers the first broadcast.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        while svc.metrics().counter(names::SHARD_CACHE_BROADCASTS) == 0
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(std::time::Duration::from_millis(100));
        }
    }
    println!("\nmetrics:\n{}", svc.metrics().report());
    for s in 0..fleet {
        let completed = svc.metrics().counter(&names::shard_jobs_completed(s));
        println!("shard {s}: {completed} jobs completed");
        anyhow::ensure!(completed > 0, "sharded smoke failed: shard {s} served no jobs");
    }
    if autotuned {
        let broadcasts = svc.metrics().counter(names::SHARD_CACHE_BROADCASTS);
        println!("cross-shard cache broadcasts: {broadcasts}");
        anyhow::ensure!(
            broadcasts > 0,
            "sharded smoke failed: no cross-shard cache broadcast occurred"
        );
        println!("merged tuned classes at the router: {}", svc.cache().len());
    }
    if let Some(hub) = svc.trace_hub() {
        finish_trace(hub, args.get("trace-log"), !args.has("chaos-kill"))?;
    }
    if let Some(server) = &scrape {
        self_scrape(server)?;
    }
    Ok(())
}

/// The `--chaos-kill` failover round: stream a batch, kill shard 0 once it
/// has work in flight, and require that (a) the stream still completes —
/// every job resolves, as a sort or a typed error, never a hang — and (b)
/// the router redials the shard (`shards.redials >= 1`). CI runs this over
/// `--transport tcp` as the multi-node failover smoke.
#[cfg(unix)]
fn serve_chaos_round(
    svc: &crate::coordinator::ShardedService,
    requests: Vec<SortRequest>,
    jobs: usize,
) -> Result<()> {
    use std::time::{Duration, Instant};

    let router = svc
        .router()
        .ok_or_else(|| anyhow::anyhow!("--chaos-kill needs a sharded fleet (>= 2 slots)"))?;
    println!("chaos round: killing shard 0 mid-batch ({jobs} jobs)");
    let stream = svc.submit_batch_requests(requests).stream();
    let deadline = Instant::now() + Duration::from_secs(10);
    while router.inflight(0) == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    anyhow::ensure!(router.inflight(0) > 0, "chaos round: shard 0 never took a job");
    anyhow::ensure!(router.kill_shard(0), "chaos round: could not kill shard 0");
    let (mut completed, mut failed) = (0usize, 0usize);
    for result in stream {
        match result {
            Ok(out) => {
                anyhow::ensure!(out.valid, "chaos round: job {} failed validation", out.id);
                completed += 1;
            }
            Err(_) => failed += 1,
        }
    }
    anyhow::ensure!(
        completed + failed == jobs,
        "chaos round: {completed} completed + {failed} failed != {jobs} submitted"
    );
    anyhow::ensure!(completed > 0, "chaos round: no job survived the kill");
    println!(
        "chaos round: {completed} completed + {failed} failed = {jobs} submitted \
         (no job hung)"
    );
    let deadline = Instant::now() + Duration::from_secs(15);
    while svc.metrics().counter(names::SHARDS_REDIALS) == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    let redials = svc.metrics().counter(names::SHARDS_REDIALS);
    anyhow::ensure!(redials >= 1, "chaos round: shard 0 was never redialed");
    println!("chaos round: shard redials observed: {redials}");
    Ok(())
}

#[cfg(not(unix))]
fn serve_sharded(
    _args: &Args,
    _jobs: usize,
    _n: usize,
    _workers: usize,
    _threads: usize,
    _shards: usize,
) -> Result<()> {
    bail!("serve --shards requires Unix-domain sockets (unix-only)")
}

/// `evosort shard-worker` — the worker-process side of the sharded service.
///
/// Two modes:
///
/// * `--connect <endpoint>` — dial a waiting router and serve it until told
///   to shut down. This is how [`ShardRouter`](crate::coordinator::ShardRouter)
///   spawns its local shards (it passes the resolved listen address).
/// * `--listen <endpoint>` — bind, announce
///   `shard-worker listening on <endpoint>` on stdout, and serve routers
///   one at a time, re-listening when one disconnects. This is the
///   standalone mode for remote hosts: start it there, then point a router
///   at it with `serve --connect tcp://host:port`. Exits only on a
///   `Shutdown` frame.
///
/// `--socket <path>` is the legacy spelling of `--connect unix://<path>`.
pub fn cmd_shard_worker(args: &Args) -> Result<()> {
    #[cfg(unix)]
    {
        use crate::coordinator::shard::worker::{self, ShardWorkerConfig};
        use crate::coordinator::Endpoint;

        // Production-default base: the router forwards every knob it wants
        // explicitly, so unforwarded knobs get library defaults here.
        let autotune = if args.has("autotune") {
            Some(autotune_policy_from(args, crate::autotune::AutotunePolicy::default())?)
        } else {
            None
        };
        let config = ShardWorkerConfig {
            shard_id: args.usize_or("shard-id", 0)?,
            service: ServiceConfig::sized(
                args.usize_or("workers", 2)?,
                args.usize_or("sort-threads", 2)?,
                args.usize_or("queue-capacity", 64)?,
            )
            .with_exec(exec_mode_of(args)?)
            .with_external(external_config_of(args)?),
            publish_interval: std::time::Duration::from_millis(args.u64_or("publish-ms", 200)?),
            trace: args.has("trace"),
        };
        match (args.get("connect"), args.get("listen"), args.get("socket")) {
            (Some(text), None, None) => worker::run(&text.parse::<Endpoint>()?, config),
            (None, Some(text), None) => worker::run_listening(&text.parse::<Endpoint>()?, config),
            (None, None, Some(path)) => {
                worker::run(&Endpoint::unix(std::path::PathBuf::from(path)), config)
            }
            _ => bail!(
                "shard-worker requires exactly one of --connect <endpoint> (dial a router), \
                 --listen <endpoint> (standalone: wait for routers), or --socket <path> \
                 (legacy unix --connect)"
            ),
        }
    }
    #[cfg(not(unix))]
    {
        let _ = args;
        bail!("shard-worker requires Unix-domain sockets (unix-only)")
    }
}

/// `evosort serve --autotune` — the online-adaptation demo/smoke: feed the
/// service repeated batches of one workload shape until the background tuner
/// publishes fingerprint-keyed parameters into the cache (bounded by
/// `--rounds`), then report what it learned. Exits non-zero if the cache
/// gained no entries — CI uses this as the autotune smoke test.
fn serve_autotune(
    args: &Args,
    jobs: usize,
    n: usize,
    workers: usize,
    threads: usize,
    dtype: Dtype,
) -> Result<()> {
    // Demo-eager observation thresholds (see `demo_autotune_base`), but
    // production defaults for the noise margin (min_improvement_pct 2%):
    // the CLI must not silently inherit the test-only 0% margin of
    // `AutotunePolicy::quick()`, which would let timing noise churn (and
    // persist) the cache. The CI smoke passes `--min-improvement 0`
    // explicitly.
    let policy = autotune_policy_from(args, demo_autotune_base())?;
    let rounds = args.usize_or("rounds", 12)?;
    let dist = dist_of(args)?;
    let seed = args.u64_or("seed", 42)?;
    let (sort_threads, queue_capacity) = serve_sizing(args, workers, threads)?;
    let svc = SortService::new(
        ServiceConfig::sized(workers, sort_threads, queue_capacity)
            .with_autotune(policy)
            .with_exec(exec_mode_of(args)?)
            .with_external(external_config_of(args)?),
    );
    println!(
        "autotune service: {workers} workers, up to {rounds} rounds of {jobs} {} {dtype} jobs \
         of {} elements",
        dist.name(),
        fmt_count(n)
    );
    for round in 0..rounds {
        let batch: Vec<SortRequest> = (0..jobs)
            .map(|i| {
                let data =
                    data::generate_i64(n, dist, seed ^ (round * jobs + i) as u64, threads);
                let payload = SortPayload::from_i64_values(data, dtype);
                SortRequest::from_payload(payload).with_dist(dist.name())
            })
            .collect();
        let report = svc.submit_batch_requests(batch).wait();
        anyhow::ensure!(report.stats.invalid == 0, "{} jobs invalid", report.stats.invalid);
        anyhow::ensure!(report.stats.failed == 0, "{} jobs failed", report.stats.failed);
        println!(
            "round {:>2}: {:>7.0} jobs/s  p50 {}  p99 {}  cache {}/{}  tuner: {} cycles, {} published",
            round + 1,
            report.stats.jobs_per_sec,
            fmt_secs(report.stats.p50_secs),
            fmt_secs(report.stats.p99_secs),
            report.stats.cache_hits,
            report.stats.cache_hits + report.stats.cache_misses,
            svc.metrics().counter(names::TUNER_CYCLES),
            svc.metrics().counter(names::TUNER_PUBLISHES),
        );
        // Adapted this run (a restored --cache-file alone doesn't count) and
        // observed serving cached params.
        if svc.metrics().counter(names::TUNER_PUBLISHES) > 0
            && svc.metrics().counter(names::PARAMS_CACHE_HIT) > 0
        {
            break;
        }
    }
    // Grace period: let in-flight tuning cycles land.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
    while svc.metrics().counter(names::TUNER_PUBLISHES) == 0
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    println!("\nmetrics:\n{}", svc.metrics().report());
    let classes = svc.cache().entries();
    println!("autotuned classes: {}", classes.len());
    for (key, params) in &classes {
        println!("  band {:>2}  {}  ->  {params}", key.size_band, key.dist);
    }
    anyhow::ensure!(
        svc.metrics().counter(names::TUNER_PUBLISHES) > 0,
        "autotune smoke failed: the tuner published no parameters this run"
    );
    Ok(())
}

/// `evosort bench` — the perf-regression surface: per-kernel ×
/// per-distribution medians at service-relevant (spawn-overhead-sensitive)
/// sizes, plus the many-mid-sized-jobs service workload run in **both**
/// executor modes — the persistent parked executor against the
/// spawn-per-call baseline it replaced.
///
/// * `--json FILE` writes the `evosort-bench-v2` report (the `BENCH_*.json`
///   trajectory): per-point medians/scores plus, for kernel points, a
///   per-phase breakdown from one `PhaseTimer`-instrumented pass. Committed
///   `evosort-bench-v1` baselines still parse and compare on shared ids.
/// * `--compare BASE` diffs hardware-normalised scores against a committed
///   baseline and exits non-zero on a > `--max-regression` (default 2x)
///   collapse. Unmeasured seed baselines are skipped (bootstrap mode).
/// * `--min-service-speedup R` exits non-zero unless parked-executor service
///   throughput is at least `R` times the spawn-per-call baseline (CI uses
///   1.3).
pub fn cmd_bench(args: &Args) -> Result<()> {
    if args.get("scale-div").is_some() {
        // Validate before exporting: an unparsable value silently falling
        // back to the default would bench (and record) the wrong sizes.
        let div = args.usize_or("scale-div", 100)?;
        anyhow::ensure!(div >= 1, "--scale-div must be >= 1");
        std::env::set_var("EVOSORT_BENCH_SCALE_DIV", div.to_string());
    }
    let threads = threads_of(args)?;
    let workers = args.usize_or("workers", 2)?;
    let jobs = args.usize_or("jobs", 32)?;
    let mut cfg = BenchConfig::from_env();
    cfg.repeats = args.usize_or("repeats", cfg.repeats)?;
    cfg.warmup = args.usize_or("warmup", cfg.warmup)?;
    let min_service_speedup = args.f64_or("min-service-speedup", 0.0)?;
    let max_regression = args.f64_or("max-regression", 2.0)?;
    // The spawn-overhead-sensitive point the issue targets: mid-sized
    // arrays, where per-call thread spawns used to rival the sort itself.
    let n = scaled_size(10_000_000);

    crate::bench_harness::banner(
        "bench",
        "per-kernel medians + parked-vs-spawn service throughput (the BENCH_*.json surface)",
    );
    let mut entries: Vec<BenchEntry> = Vec::new();
    let mut table = Table::new(&["point", "median", "throughput", "score"]);

    // Kernel matrix: every Algorithm-6 branch plus the std baseline, across
    // the distributions the service fingerprinter separates.
    let dists =
        [Distribution::Uniform, Distribution::Zipf, Distribution::Sorted, Distribution::FewUnique];
    let sorter = AdaptiveSorter::new(threads);
    let base_params = SymbolicModel::paper().params_for(n);
    let mut scratch: Vec<i64> = Vec::new();
    for dist in dists {
        let data = data::generate_i64(n, dist, 42, threads);
        let m_std = measure(&cfg, "std", || data.clone(), |mut d| d.sort_unstable());
        let std_median = m_std.median();
        push_entry(
            &mut entries,
            &mut table,
            format!("kernel/std/{}/n{n}", dist.name()),
            &m_std,
            n as f64 / std_median.max(1e-12),
            1.0,
        );
        // `base_params.algorithm` is what adaptive dispatch would pick here
        // (Radix, per the symbolic model), so a separate "adaptive" row
        // would just re-measure the radix row — every Algorithm-6 branch is
        // already covered by these three.
        let kernels = [("radix", ACode::Radix), ("merge", ACode::Merge), ("sample", ACode::Sample)];
        for (name, algo) in kernels {
            let p = SortParams { algorithm: algo, ..base_params };
            let m = measure(
                &cfg,
                name,
                || data.clone(),
                |mut d| sorter.sort_i64_with_scratch(&mut d, &p, &mut scratch),
            );
            let score = std_median / m.median().max(1e-12);
            push_entry_with_phases(
                &mut entries,
                &mut table,
                format!("kernel/{name}/{}/n{n}", dist.name()),
                &m,
                n as f64 / m.median().max(1e-12),
                score,
                kernel_phases(&sorter, &data, &p),
            );
        }
        // Digit-width matrix: the radix kernel across the GA-tunable widths
        // (genome gene 5) on the uniform point. The `kernel/radix` rows
        // above measure whatever width the symbolic model seeds (W8), so
        // their ids — and the v1/v2 baseline compare armed on them — stay
        // untouched; the explicit w6/w8/w11 group makes the three-way
        // comparison readable off one row cluster.
        if matches!(dist, Distribution::Uniform) {
            for width in [RadixWidth::W6, RadixWidth::W8, RadixWidth::W11] {
                // fallback 0: these rows measure the kernel itself, so the
                // sort must reach it even at scaled-down CI sizes where the
                // symbolic fallback threshold would shunt to sort_unstable
                // (which would also trip the phase-coverage gate below).
                let p = SortParams {
                    algorithm: ACode::Radix,
                    radix_width: width,
                    fallback_threshold: 0,
                    ..base_params
                };
                let m = measure(
                    &cfg,
                    "radix-w",
                    || data.clone(),
                    |mut d| sorter.sort_i64_with_scratch(&mut d, &p, &mut scratch),
                );
                let score = std_median / m.median().max(1e-12);
                let phases = kernel_phases(&sorter, &data, &p);
                // Smoke gate: the instrumented pass must show time in every
                // `kernel.radix.*` phase — a silently skipped count/scan/
                // scatter would make the width rows unreadable.
                check_radix_phase_coverage(&phases)?;
                push_entry_with_phases(
                    &mut entries,
                    &mut table,
                    format!("kernel/radix-w{}/{}/n{n}", width.bits(), dist.name()),
                    &m,
                    n as f64 / m.median().max(1e-12),
                    score,
                    phases,
                );
            }
        }
    }

    // Out-of-core point: a beyond-budget sort through the external sorter
    // (budget = 1/4 of the payload forces several spilled runs), with the
    // v2 per-phase split — run formation + spill writes vs the loser-tree
    // merge — as the `extsort/` row group. This is the perf surface the
    // spill genes tune; the phase medians show where a policy change moved
    // the time.
    {
        let xn = scaled_size(4_000_000);
        let budget = xn * 2; // bytes: n * 8 / 4
        let spill_root =
            std::env::temp_dir().join(format!("evosort-bench-spill-{}", std::process::id()));
        std::fs::create_dir_all(&spill_root)?;
        let config =
            crate::extsort::ExternalConfig::new(budget).with_spill_dir(spill_root.clone());
        let ext = crate::extsort::ExtParams::default();
        let xp = SymbolicModel::paper().params_for(xn);
        let data = data::generate_i64(xn, Distribution::Uniform, 42, threads);
        let m_std = measure(&cfg, "std", || data.clone(), |mut d| d.sort_unstable());
        let mut ext_scratch = crate::sort::SortScratch::new();
        let m = measure(
            &cfg,
            "extsort",
            || data.clone(),
            |d| {
                let mut out = 0usize;
                crate::extsort::ExternalSorter::new(&sorter, &config)
                    .sort_streaming(
                        d,
                        &xp,
                        ext,
                        &mut ext_scratch,
                        &mut |chunk| {
                            out += chunk.len();
                            Ok(())
                        },
                        &mut || false,
                    )
                    .expect("bench external sort failed");
                assert_eq!(out, xn, "external sort dropped elements");
            },
        );
        // Score against the in-memory std sort of the same payload — the
        // out-of-core tax, hardware-normalised like the kernel rows.
        let score = m_std.median() / m.median().max(1e-12);
        push_entry_with_phases(
            &mut entries,
            &mut table,
            format!("extsort/stream/uniform/n{xn}"),
            &m,
            xn as f64 / m.median().max(1e-12),
            score,
            extsort_phases(&sorter, &data, &xp, ext, &config),
        );
        let _ = std::fs::remove_dir_all(&spill_root);
    }

    // Service workload: many mid-sized jobs through the batched path, once
    // per executor mode. The parked entry's score is its throughput edge
    // over the spawn-per-call baseline — the headline this PR gates on.
    let spawn_wall =
        bench_service_batch(&cfg, crate::exec::ExecMode::SpawnPerCall, jobs, n, workers, threads)?;
    let parked_wall =
        bench_service_batch(&cfg, crate::exec::ExecMode::Parked, jobs, n, workers, threads)?;
    let spawn_jps = jobs as f64 / spawn_wall.median().max(1e-12);
    let parked_jps = jobs as f64 / parked_wall.median().max(1e-12);
    let ratio = parked_jps / spawn_jps.max(1e-12);
    push_entry(
        &mut entries,
        &mut table,
        format!("service/spawn/j{jobs}xn{n}"),
        &spawn_wall,
        (jobs * n) as f64 / spawn_wall.median().max(1e-12),
        1.0,
    );
    push_entry(
        &mut entries,
        &mut table,
        format!("service/parked/j{jobs}xn{n}"),
        &parked_wall,
        (jobs * n) as f64 / parked_wall.median().max(1e-12),
        ratio,
    );
    table.print();
    println!(
        "service throughput ({jobs} x {} jobs): parked {parked_jps:.1} jobs/s vs \
         spawn-per-call {spawn_jps:.1} jobs/s -> {ratio:.2}x",
        fmt_count(n)
    );

    let doc = BenchDoc {
        schema: bench_json::SCHEMA.into(),
        provenance: bench_json::PROVENANCE_MEASURED.into(),
        threads,
        scale_div: scale_div(),
        entries,
    };
    if let Some(path) = args.get("json") {
        std::fs::write(path, doc.to_json())?;
        println!("wrote {path}");
    }
    if let Some(base_path) = args.get("compare") {
        let base = BenchDoc::from_json(&std::fs::read_to_string(base_path)?)?;
        let cmp = bench_json::compare(&base, &doc, max_regression);
        if base.provenance == bench_json::PROVENANCE_SEED {
            println!(
                "baseline {base_path} is an unmeasured seed — bootstrap mode \
                 ({} entries skipped); commit a measured report to arm the gate",
                cmp.skipped
            );
        } else {
            println!(
                "compared {} scores against {base_path} ({} skipped): {}",
                cmp.compared,
                cmp.skipped,
                if cmp.passed() { "ok" } else { "REGRESSED" }
            );
            // A measured baseline whose entry ids no longer pair with this
            // run (e.g. the bench matrix or default sizes changed) would
            // pass vacuously forever — that is a disarmed gate, not a pass.
            anyhow::ensure!(
                cmp.compared > 0,
                "bench gate: no entry of the measured baseline {base_path} matches this run's \
                 ids — re-seed the baseline from this run's report"
            );
        }
        for (id, was, now) in &cmp.regressions {
            println!("  regression: {id} score {was:.3} -> {now:.3}");
        }
        anyhow::ensure!(
            cmp.passed(),
            "bench gate: {} entries regressed more than {max_regression}x",
            cmp.regressions.len()
        );
    }
    if min_service_speedup > 0.0 {
        anyhow::ensure!(
            ratio >= min_service_speedup,
            "bench gate: parked executor is only {ratio:.2}x the spawn-per-call baseline \
             (required {min_service_speedup:.2}x)"
        );
    }
    Ok(())
}

/// Record one bench point: a table row plus a report entry.
fn push_entry(
    entries: &mut Vec<BenchEntry>,
    table: &mut Table,
    id: String,
    m: &crate::bench_harness::Measurement,
    throughput: f64,
    score: f64,
) {
    push_entry_with_phases(entries, table, id, m, throughput, score, Vec::new());
}

/// [`push_entry`] carrying a v2 per-phase breakdown (kernel points only;
/// service/std points have no phase-instrumented path).
fn push_entry_with_phases(
    entries: &mut Vec<BenchEntry>,
    table: &mut Table,
    id: String,
    m: &crate::bench_harness::Measurement,
    throughput: f64,
    score: f64,
    phases: Vec<(String, f64)>,
) {
    table.row(&[
        id.clone(),
        fmt_secs(m.median()),
        if throughput > 0.0 { format!("{:.1} Melem/s", throughput / 1e6) } else { "-".into() },
        format!("{score:.3}"),
    ]);
    entries.push(BenchEntry {
        id,
        median_secs: m.median(),
        mean_secs: m.summary.mean,
        stddev_secs: m.summary.stddev,
        throughput,
        score,
        phases,
    });
}

/// Phase-coverage gate for the radix width-matrix rows: every
/// `kernel.radix.*` phase (minmax, count, scan, scatter, copyback) must
/// report nonzero time in the instrumented pass. Guards the three-phase
/// kernel's timer wiring — a phase that stops being timed would otherwise
/// just vanish from the v2 report.
fn check_radix_phase_coverage(phases: &[(String, f64)]) -> Result<()> {
    for want in names::KERNEL_PHASES.iter().filter(|p| p.starts_with("kernel.radix.")) {
        let secs = phases.iter().find(|(p, _)| p == want).map(|(_, s)| *s);
        anyhow::ensure!(
            secs.is_some_and(|s| s > 0.0),
            "bench smoke: radix phase {want} reported no time (got {phases:?})"
        );
    }
    Ok(())
}

/// One extra instrumented pass for a kernel bench point: run the sort with
/// the [`PhaseTimer`](crate::obs::PhaseTimer) armed and report where the
/// time went — the v2 `phases` map (`kernel.<name>.<phase>` → seconds).
fn kernel_phases(sorter: &AdaptiveSorter, data: &[i64], p: &SortParams) -> Vec<(String, f64)> {
    let mut d = data.to_vec();
    let mut scratch = Vec::new();
    let mut timer = crate::obs::PhaseTimer::enabled();
    sorter.sort_i64_timed(&mut d, p, &mut scratch, &mut timer);
    let mut phases: Vec<(String, f64)> = timer
        .drain()
        .into_iter()
        .map(|(phase, secs)| (phase.metric_name().to_string(), secs))
        .collect();
    phases.sort_by(|a, b| a.0.cmp(&b.0));
    phases
}

/// One extra instrumented out-of-core pass: where the external sort's time
/// went, split between run formation, spill writes, and the merge (the
/// `kernel.ext.*` phase rows) plus the per-kernel phases of the run sorts
/// themselves.
fn extsort_phases(
    sorter: &AdaptiveSorter,
    data: &[i64],
    p: &SortParams,
    ext: crate::extsort::ExtParams,
    config: &crate::extsort::ExternalConfig,
) -> Vec<(String, f64)> {
    let mut scratch = crate::sort::SortScratch::new();
    scratch.timer_mut().set_enabled(true);
    crate::extsort::ExternalSorter::new(sorter, config)
        .sort_streaming(data.to_vec(), p, ext, &mut scratch, &mut |_chunk| Ok(()), &mut || false)
        .expect("instrumented external sort failed");
    let mut phases: Vec<(String, f64)> = scratch
        .timer_mut()
        .drain()
        .into_iter()
        .map(|(phase, secs)| (phase.metric_name().to_string(), secs))
        .collect();
    phases.sort_by(|a, b| a.0.cmp(&b.0));
    phases
}

/// One service-workload measurement: a batch of `jobs` mid-sized mixed
/// distribution i64 jobs through `submit_batch_requests`, on a service whose
/// kernels run in the given executor mode. Returns the wall-clock
/// measurement for the whole batch.
fn bench_service_batch(
    cfg: &BenchConfig,
    mode: crate::exec::ExecMode,
    jobs: usize,
    n: usize,
    workers: usize,
    threads: usize,
) -> Result<crate::bench_harness::Measurement> {
    let svc = SortService::new(
        ServiceConfig::sized(workers, (threads / workers.max(1)).max(1), jobs.max(64))
            .with_exec(mode),
    );
    let dists = [Distribution::Uniform, Distribution::Zipf, Distribution::NearlySorted];
    let payloads: Vec<Vec<i64>> = (0..jobs)
        .map(|i| data::generate_i64(n, dists[i % dists.len()], i as u64, threads))
        .collect();
    let mut failed = 0usize;
    let m = measure(
        cfg,
        mode.name(),
        || payloads.iter().map(|p| SortRequest::new(p.clone())).collect::<Vec<_>>(),
        |reqs| {
            let report = svc.submit_batch_requests(reqs).wait();
            failed += report.stats.failed + report.stats.invalid;
        },
    );
    anyhow::ensure!(failed == 0, "service bench: {failed} failed/invalid jobs");
    Ok(m)
}

/// `evosort trace FILE [--check]` — summarize a `--trace-log` JSONL file:
/// per-phase kernel p50/p99, end-to-end slowest traces, failure breakdown,
/// tuner decisions, and the span-chain completeness check. With `--check`,
/// exits non-zero when any chain is incomplete — the CI traced-serve smoke
/// gates on this.
pub fn cmd_trace(args: &Args) -> Result<()> {
    let Some(path) = args.operand.as_deref().or_else(|| args.get("file")) else {
        bail!("usage: evosort trace <trace.jsonl> [--check]");
    };
    let events = crate::obs::jsonl::read_events(std::path::Path::new(path))?;
    let summary = crate::obs::report::summarize(&events);
    print!("{}", crate::obs::report::render(&summary));
    if args.has("check") {
        anyhow::ensure!(
            summary.problems.is_empty(),
            "trace check failed: {} incomplete span chain(s) in {path}",
            summary.problems.len()
        );
        anyhow::ensure!(
            summary.traces > 0,
            "trace check failed: {path} contains no job traces"
        );
        println!("trace check: ok ({} complete traces)", summary.traces);
    }
    Ok(())
}

/// `evosort info` — environment report.
pub fn cmd_info(_args: &Args) -> Result<()> {
    println!("evosort {} — paper reproduction build", env!("CARGO_PKG_VERSION"));
    println!("threads available: {}", default_threads());
    let dir = Manifest::default_dir();
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts dir: {} ({} entries)", m.dir.display(), m.entries.len());
            for e in &m.entries {
                println!("  {} batch={} tile={} ({})", e.kind, e.batch, e.tile, e.path.display());
            }
            match XlaTileSorter::new(&m) {
                Ok(b) => println!("PJRT backend: OK (tile={} batch={})", b.tile_size_pub(), b.batch()),
                Err(e) => println!("PJRT backend: FAILED ({e})"),
            }
        }
        Err(e) => println!("artifacts: not found ({e}); run `make artifacts`"),
    }
    Ok(())
}

impl XlaTileSorter {
    fn tile_size_pub(&self) -> usize {
        use crate::sort::TileSorter;
        self.tile_size()
    }
}
