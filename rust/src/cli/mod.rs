//! Command-line interface (hand-rolled — no `clap` in the offline build).
//!
//! ```text
//! evosort <command> [--flag value] [--switch]
//!
//! commands:
//!   sort      sort one generated dataset and report timing
//!   tune      run GA tuning and print the convergence table (Figs. 2–6)
//!   pipeline  the paper's master pipeline (Algorithm 1) over several sizes
//!   symbolic  symbolic-model parameters / fit from a GA sweep (§7)
//!   repro     regenerate a paper table (--table 1|2)
//!   bench     per-kernel medians + parked-vs-spawn service throughput,
//!             with a JSON report and regression gate (--json / --compare)
//!   serve     run the sort service demo (concurrent jobs + metrics;
//!             --shards N runs it cross-process; --trace-log / --metrics-addr
//!             turn on end-to-end tracing and the Prometheus scrape endpoint;
//!             --memory-budget BYTES escalates oversized jobs to the
//!             out-of-core spill sorter)
//!   trace     summarize a trace JSONL file (per-phase p50/p99, slowest
//!             spans; --check validates span-chain invariants)
//!   info      platform, artifact and configuration report
//! ```
//!
//! (`shard-worker` also exists as a subcommand: the worker-process side of
//! the sharded service — spawned by the shard router for local shards
//! (`--connect`), or started standalone on remote hosts (`--listen
//! tcp://…`) for a router to dial with `serve --connect`.)

// Enforced boundary of the unsafe audit surface (see README
// “Correctness tooling”): argument parsing and dispatch stay entirely safe.
#![forbid(unsafe_code)]

pub mod commands;

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: one positional command, an optional positional
/// operand (`evosort trace out.jsonl`), plus `--key value` / `--switch`
/// flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    /// The operand after the command, when given (`trace <file>`).
    pub operand: Option<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse raw args (excluding argv[0]).
    pub fn parse(raw: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // A flag is a switch when the next token is absent or another flag.
                match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        args.flags.insert(name.to_string(), it.next().unwrap().clone());
                    }
                    _ => args.switches.push(name.to_string()),
                }
            } else if args.command.is_empty() {
                args.command = tok.clone();
            } else if args.operand.is_none() {
                args.operand = Some(tok.clone());
            } else {
                bail!("unexpected positional argument {tok:?}");
            }
        }
        Ok(args)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Parse a numeric flag supporting scientific notation (`1e7`, `5e8`).
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => parse_count(v).with_context(|| format!("--{name}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        Ok(self.usize_or(name, default as usize)? as u64)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}")),
        }
    }

    /// Comma-separated list of counts (`--sizes 1e6,1e7,5e7`).
    pub fn sizes_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|tok| parse_count(tok.trim()))
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("--{name}")),
        }
    }
}

/// Parse `"10000000"`, `"1e7"` or `"2.5e6"` into a count.
pub fn parse_count(s: &str) -> Result<usize> {
    if let Ok(v) = s.parse::<usize>() {
        return Ok(v);
    }
    let f: f64 = s.parse().with_context(|| format!("not a number: {s:?}"))?;
    if !(f.is_finite() && f >= 0.0 && f <= 1e18) {
        bail!("count out of range: {s:?}");
    }
    Ok(f.round() as usize)
}

/// Top-level usage text.
pub const USAGE: &str = "\
EvoSort — GA-based adaptive parallel sorting (paper reproduction)

USAGE: evosort <command> [flags]

COMMANDS
  sort      --n 1e7 [--dist uniform] [--seed 42] [--threads N]
            [--algo auto|merge|radix|xla|baseline-quicksort|baseline-mergesort|std]
            [--tune] [--symbolic]
  tune      --n 1e7 [--generations 10] [--population 30] [--sample-cap 4e6]
            [--dist uniform] [--seed ..] [--threads N]
  pipeline  [--sizes 1e6,1e7] [--dist uniform] [--ga | --symbolic | --fixed]
            [--generations ..] [--population ..] [--threads N]
  symbolic  [--paper] [--sweep 1e5,1e6,1e7] [--n 1e8] (prints params; with
            --sweep, fits quadratics to a fresh GA sweep — Figures 7–11)
  repro     --table 1|2 [--scale-div 100] (regenerate a paper table, scaled)
  bench     [--json out.json] [--compare base.json] [--max-regression 2.0]
            [--min-service-speedup 1.3] [--jobs 32] [--workers 2]
            [--repeats N] [--warmup N] [--scale-div 100]
            (per-kernel x per-distribution medians at spawn-sensitive sizes,
            plus the many-mid-sized-jobs service workload on the persistent
            parked executor vs the spawn-per-call baseline; --json emits the
            BENCH_*.json report, --compare gates on score regressions)
  serve     [--jobs 16] [--workers 2] [--n 1e6] [--dtype i64|i32|u64|f64]
            [--sort-threads N] (fork-join width per sort; default: the
            thread budget split across workers)
            [--queue-capacity 64] (pending-job admission bound per service)
            [--exec parked|spawn] (kernel execution backend; default parked)
            [--batch] (service demo + metrics; --dtype picks the key dtype —
            floats sort in IEEE total_cmp order; --batch submits one mixed
            batch and reports p50/p99 latency and jobs/sec)
            [--autotune] [--rounds 12] [--min-obs 8] [--tuner-generations 2]
            [--tuner-population 8] [--cpu-share 0.5] [--min-improvement 2.0]
            [--cache-file f.txt]
            (online tuner: repeated batches of one shape; the background GA
            refines fingerprint-keyed params in the tuning cache while
            traffic flows, and the run fails if nothing was learned)
            [--shards N] (N >= 2: cross-process service — a router spawns N
            shard-worker processes and routes mixed-dtype batches across
            them; with --autotune each shard tunes locally and caches sync
            through the router, and the run fails unless every shard served
            jobs and a cross-shard broadcast occurred)
            [--transport unix|tcp] (local-shard link; default unix)
            [--listen EP] (local-shard listen base, e.g. tcp://127.0.0.1:0;
            its scheme selects the transport)
            [--connect EP1,EP2] (dial externally started
            `shard-worker --listen` workers into the fleet — tcp://host:port
            reaches other hosts; they are redialed with backoff on failure)
            [--chaos-kill] (failover smoke: kill shard 0 mid-batch, require
            the batch to complete and the shard to be redialed)
            [--trace] (end-to-end tracing: per-job span events on every
            shard — submitted/queued/dispatched/kernel-phase/terminal —
            merged into one fleet timeline at the router)
            [--trace-log FILE] (append the merged timeline as
            evosort-trace-v1 JSONL; implies --trace — inspect it with
            `evosort trace FILE`)
            [--metrics-addr HOST:PORT] (serve Prometheus text-format
            metrics over HTTP for the run and self-scrape once at the end;
            port 0 picks a free port)
            [--memory-budget BYTES] (out-of-core escalation: jobs whose
            payload exceeds the budget sort via spill-to-disk runs and a
            k-way streaming merge; the run then fails unless something
            spilled and the spill root is left clean — the CI spill smoke.
            Single-process serve and shard-worker only)
            [--spill-dir DIR] (spill-run root, needs --memory-budget;
            default: the OS temp dir)
  trace     FILE [--check] (span-tree summary of a --trace-log file:
            per-phase and end-to-end p50/p99, slowest traces, per-shard
            event counts; --check exits non-zero on incomplete span chains)
  shard-worker
            --connect EP (dial a waiting router — how local shards start) |
            --listen EP (standalone: bind, print
            `shard-worker listening on EP`, serve routers one at a time,
            re-listen on disconnect; exits on a Shutdown frame) |
            --socket PATH (legacy unix --connect)
            [--workers N] [--sort-threads N] [--queue-capacity N]
            [--publish-ms MS] [--exec parked|spawn] [--autotune ...]
            [--memory-budget BYTES] [--spill-dir DIR]
            [--trace] (emit span events and stream them to the router)
  info      (platform, threads, artifact status)

FLAGS common: --threads N (default: all cores), --seed S, --dist DIST
DISTS: uniform zipf gaussian sorted reverse nearly-sorted few-unique organ-pipe constant
ENV:   EVOSORT_LOG=debug, EVOSORT_ARTIFACTS=dir, EVOSORT_BENCH_SCALE_DIV=N
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(&v.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_command_flags_switches() {
        let a = parse(&["sort", "--n", "1e7", "--tune", "--dist", "zipf"]);
        assert_eq!(a.command, "sort");
        assert_eq!(a.usize_or("n", 0).unwrap(), 10_000_000);
        assert_eq!(a.str_or("dist", "uniform"), "zipf");
        assert!(a.has("tune"));
        assert!(!a.has("symbolic"));
    }

    #[test]
    fn trailing_switch() {
        let a = parse(&["pipeline", "--symbolic"]);
        assert!(a.has("symbolic"));
    }

    #[test]
    fn sizes_list() {
        let a = parse(&["pipeline", "--sizes", "1e6,2.5e6,1000"]);
        assert_eq!(a.sizes_or("sizes", &[]).unwrap(), vec![1_000_000, 2_500_000, 1000]);
    }

    #[test]
    fn count_notations() {
        assert_eq!(parse_count("12345").unwrap(), 12345);
        assert_eq!(parse_count("1e7").unwrap(), 10_000_000);
        assert_eq!(parse_count("5e8").unwrap(), 500_000_000);
        assert!(parse_count("abc").is_err());
        assert!(parse_count("-5.0").is_err());
    }

    #[test]
    fn one_operand_allowed_then_rejects() {
        let a = parse(&["trace", "out.jsonl"]);
        assert_eq!(a.command, "trace");
        assert_eq!(a.operand.as_deref(), Some("out.jsonl"));
        let r = Args::parse(&["a".into(), "b".into(), "c".into()]);
        assert!(r.is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["tune"]);
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert_eq!(a.f64_or("x", 1.5).unwrap(), 1.5);
    }
}
