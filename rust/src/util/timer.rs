//! Wall-clock timing helpers used by the fitness function, the bench harness
//! and the metrics layer.

use std::time::{Duration, Instant};

/// Time a closure, returning `(result, elapsed_seconds)`.
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed().as_secs_f64())
}

/// A simple re-startable stopwatch accumulating total elapsed time.
#[derive(Debug)]
pub struct Stopwatch {
    accumulated: Duration,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { accumulated: Duration::ZERO, started: None }
    }

    /// Create a stopwatch that is already running.
    pub fn started() -> Self {
        Stopwatch { accumulated: Duration::ZERO, started: Some(Instant::now()) }
    }

    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    pub fn stop(&mut self) {
        if let Some(s) = self.started.take() {
            self.accumulated += s.elapsed();
        }
    }

    pub fn is_running(&self) -> bool {
        self.started.is_some()
    }

    /// Total elapsed time including any in-flight interval.
    pub fn elapsed(&self) -> Duration {
        match self.started {
            Some(s) => self.accumulated + s.elapsed(),
            None => self.accumulated,
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn reset(&mut self) {
        self.accumulated = Duration::ZERO;
        self.started = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_returns_result_and_positive_elapsed() {
        let (v, secs) = time(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499_500);
        assert!(secs >= 0.0);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        assert!(!sw.is_running());
        sw.start();
        assert!(sw.is_running());
        std::thread::sleep(Duration::from_millis(2));
        sw.stop();
        let t1 = sw.elapsed_secs();
        assert!(t1 > 0.0);
        sw.start();
        std::thread::sleep(Duration::from_millis(2));
        sw.stop();
        assert!(sw.elapsed_secs() > t1);
        sw.reset();
        assert_eq!(sw.elapsed(), Duration::ZERO);
    }

    #[test]
    fn stopwatch_started_runs() {
        let sw = Stopwatch::started();
        assert!(sw.is_running());
        assert!(sw.elapsed_secs() >= 0.0);
    }

    #[test]
    fn double_start_is_noop() {
        let mut sw = Stopwatch::new();
        sw.start();
        sw.start();
        sw.stop();
        sw.stop(); // double stop is a no-op too
        assert!(!sw.is_running());
    }
}
