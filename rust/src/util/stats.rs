//! Summary statistics over f64 samples — used by the GA generation tracker,
//! the bench harness, and the metrics layer.

/// Summary of a set of samples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty slice.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len();
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let min = sorted[0];
        let max = sorted[n - 1];
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Some(Summary { n, min, max, mean, median, stddev: var.sqrt() })
    }
}

/// Streaming mean/variance via Welford's algorithm; O(1) memory, suitable for
/// hot-path metrics where we cannot keep every sample.
#[derive(Debug, Clone, Copy, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_odd_median() {
        let s = Summary::of(&[5.0, 1.0, 3.0]).unwrap();
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn summary_empty() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn welford_matches_summary() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64) * 0.37 - 5.0).collect();
        let s = Summary::of(&xs).unwrap();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert_eq!(w.count(), 100);
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.stddev() - s.stddev).abs() < 1e-9);
        assert_eq!(w.min(), s.min);
        assert_eq!(w.max(), s.max);
    }

    #[test]
    fn welford_merge_equals_single_stream() {
        let xs: Vec<f64> = (0..50).map(|i| (i as f64).sin()).collect();
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..20] {
            a.push(x);
        }
        for &x in &xs[20..] {
            b.push(x);
        }
        let mut all = Welford::new();
        for &x in &xs {
            all.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }
}
