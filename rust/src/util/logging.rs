//! Minimal leveled logger (stderr), controlled by `EVOSORT_LOG`.
//!
//! Levels: `error` < `warn` < `info` < `debug` < `trace`. Defaults to `info`.
//! This replaces the `log`/`env_logger` stack, which is unavailable offline.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Once;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn from_env(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static INIT: Once = Once::new();

/// Initialise the log level from `EVOSORT_LOG` (idempotent).
pub fn init() {
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("EVOSORT_LOG") {
            if let Some(l) = Level::from_env(&v) {
                LEVEL.store(l as u8, Ordering::Relaxed);
            }
        }
    });
}

/// Override the log level programmatically.
pub fn set_level(level: Level) {
    INIT.call_once(|| {});
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    init();
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Emit a record; prefer the `info!`/`debug!`-style macros below.
pub fn log(level: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[evosort {:5} {module}] {args}", level.as_str());
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        assert_eq!(Level::from_env("DEBUG"), Some(Level::Debug));
        assert_eq!(Level::from_env("bogus"), None);
    }

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info);
    }
}
