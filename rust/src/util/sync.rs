//! Feature-switched synchronization primitives for model checking.
//!
//! The hand-rolled concurrent structures (`obs::ring::TraceRing`,
//! `exec::Executor`) import their atomics, locks, and threads from this module
//! instead of `std::sync` directly. In a normal build the re-exports below are
//! exactly the `std` types with zero overhead. With `--features loom` they
//! switch to the [`loom`] model checker's instrumented doubles, letting the
//! `loom_model` test modules in those files explore thread interleavings:
//!
//! ```text
//! cargo test --features loom --lib -- loom_model
//! ```
//!
//! The vendored `loom` at `rust/vendor/loom` is an offline API-compatible shim
//! (bounded stress loop instead of exhaustive permutation search) so the build
//! never needs the network; pointing Cargo at the real crates.io `loom` makes
//! every call site an exhaustive model check with no source changes.
//!
//! Two deliberate exceptions stay on `std` even under the feature:
//! * `const`-initialized `static` counters (loom atomics cannot be `const`
//!   constructed), e.g. `exec::THREAD_SPAWNS`;
//! * the process-global executor behind `OnceLock` (loom types must not
//!   outlive a single `model()` iteration).

#[cfg(feature = "loom")]
pub(crate) use loom::cell::UnsafeCell;
#[cfg(feature = "loom")]
pub(crate) use loom::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
#[cfg(feature = "loom")]
pub(crate) use loom::sync::{Arc, Condvar, Mutex};
#[cfg(feature = "loom")]
pub(crate) use loom::thread;

#[cfg(not(feature = "loom"))]
pub(crate) use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
#[cfg(not(feature = "loom"))]
pub(crate) use std::sync::{Arc, Condvar, Mutex};
#[cfg(not(feature = "loom"))]
pub(crate) use std::thread;

#[cfg(not(feature = "loom"))]
mod cell {
    /// `loom::cell::UnsafeCell`-shaped wrapper over [`std::cell::UnsafeCell`].
    ///
    /// Loom tracks every access to its `UnsafeCell` through the
    /// `with`/`with_mut` closures to detect data races; the std build lowers
    /// the same calls to plain pointer dereferences. Writing the accesses in
    /// closure form once keeps the production path and the model path
    /// byte-for-byte identical.
    #[derive(Debug, Default)]
    pub(crate) struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

    impl<T> UnsafeCell<T> {
        pub(crate) fn new(value: T) -> Self {
            Self(std::cell::UnsafeCell::new(value))
        }

        /// Run `f` with a shared raw pointer to the contents.
        pub(crate) fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
            f(self.0.get())
        }

        /// Run `f` with an exclusive raw pointer to the contents. The caller
        /// upholds aliasing discipline exactly as with `UnsafeCell::get`.
        pub(crate) fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
            f(self.0.get())
        }
    }
}
#[cfg(not(feature = "loom"))]
pub(crate) use cell::UnsafeCell;
