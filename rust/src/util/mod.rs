//! Small shared utilities: timing, logging, human-readable formatting.
//!
//! These are deliberately dependency-free (`std` only) — the offline build
//! environment carries no `log`/`tracing`/`humantime` crates, and the needs of
//! the framework are simple enough that a few hundred lines cover them. The
//! one exception is [`sync`], which swaps `std` primitives for the `loom`
//! model checker's doubles under `--features loom`.

pub mod logging;
pub mod stats;
pub(crate) mod sync;
pub mod timer;

/// Format an element count like the paper does: `1e7`, `5e8`, `1e10`.
pub fn fmt_count(n: usize) -> String {
    if n == 0 {
        return "0".to_string();
    }
    let nf = n as f64;
    let exp = nf.log10().floor() as i32;
    let mantissa = nf / 10f64.powi(exp);
    if (mantissa - 1.0).abs() < 1e-9 {
        format!("1e{exp}")
    } else if (mantissa - mantissa.round()).abs() < 1e-9 {
        format!("{:.0}e{exp}", mantissa)
    } else {
        format!("{:.2}e{exp}", mantissa)
    }
}

/// Format a byte count with binary units.
pub fn fmt_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format seconds the way the paper's tables do (4 decimal places for small
/// values, fewer for large ones).
pub fn fmt_secs(s: f64) -> String {
    if s < 0.0001 {
        format!("{:.1}us", s * 1e6)
    } else if s < 10.0 {
        format!("{s:.4}s")
    } else if s < 100.0 {
        format!("{s:.2}s")
    } else {
        format!("{s:.1}s")
    }
}

/// Number of worker threads to use by default: the full machine, like the
/// paper's "256 threads for Numba's parallel execution".
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_count_paper_style() {
        assert_eq!(fmt_count(10_000_000), "1e7");
        assert_eq!(fmt_count(500_000_000), "5e8");
        assert_eq!(fmt_count(10_000_000_000), "1e10");
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(1), "1e0");
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(0.2416), "0.2416s");
        assert_eq!(fmt_secs(11.1105), "11.11s");
        assert_eq!(fmt_secs(1164.9239), "1164.9s");
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
