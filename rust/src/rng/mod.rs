//! Deterministic, seedable pseudo-random number generation, from scratch.
//!
//! The offline environment has no `rand` crate, and reproducibility is a core
//! requirement of the paper ("we set a fixed random seed ... which makes our
//! experiments fully reproducible"). We implement:
//!
//! * [`SplitMix64`] — used to seed/expand state (Steele et al., 2014).
//! * [`Xoshiro256pp`] — the main generator (Blackman & Vigna, 2019): fast,
//!   high-quality, 256-bit state, supports `jump()` for parallel streams.
//!
//! Distribution helpers (uniform ranges via Lemire rejection, f64 in [0,1),
//! Gaussian via Box–Muller, Zipf via rejection-inversion) live in
//! [`distributions`].

pub mod distributions;

/// SplitMix64: a tiny 64-bit PRNG mainly used to derive seed material.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 expansion, as the authors recommend.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = sm.next_u64();
        }
        // All-zero state is invalid (fixed point); SplitMix64 cannot emit four
        // zeros in a row for any seed, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Xoshiro256pp { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, bound) using Lemire's multiply-shift with rejection.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        // 128-bit multiply-high technique.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform i64 in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "empty range");
        let span = (hi as i128 - lo as i128 + 1) as u128;
        if span > u64::MAX as u128 {
            // Full 64-bit span: any u64 reinterpreted works.
            return self.next_u64() as i64;
        }
        lo.wrapping_add(self.next_below(span as u64) as i64)
    }

    /// Uniform i32 in [lo, hi] inclusive.
    #[inline]
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        self.range_i64(lo as i64, hi as i64) as i32
    }

    /// Uniform usize in [0, bound).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// The xoshiro jump function: advances the state by 2^128 steps, giving
    /// 2^128 non-overlapping parallel subsequences. Used to hand each worker
    /// thread its own stream derived from one master seed.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] =
            [0x180EC6D33CFD0ABA, 0xD5A61266F0C9392C, 0xA9582618E03FC9AA, 0x39ABDC4529B1661C];
        let mut s = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    s[0] ^= self.s[0];
                    s[1] ^= self.s[1];
                    s[2] ^= self.s[2];
                    s[3] ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = s;
    }

    /// Derive `n` independent generators for parallel fills.
    pub fn streams(seed: u64, n: usize) -> Vec<Xoshiro256pp> {
        let mut base = Xoshiro256pp::seeded(seed);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(base.clone());
            base.jump();
        }
        out
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference output for seed 1234567 from the public-domain C code.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn xoshiro_deterministic() {
        let mut a = Xoshiro256pp::seeded(42);
        let mut b = Xoshiro256pp::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Xoshiro256pp::seeded(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Xoshiro256pp::seeded(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_i64_bounds_inclusive() {
        let mut r = Xoshiro256pp::seeded(9);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..20_000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi, "range endpoints should be reachable");
    }

    #[test]
    fn range_paper_interval() {
        let mut r = Xoshiro256pp::seeded(11);
        for _ in 0..1000 {
            let v = r.range_i64(-1_000_000_000, 1_000_000_000);
            assert!((-1_000_000_000..=1_000_000_000).contains(&v));
        }
    }

    #[test]
    fn next_below_uniformish() {
        let mut r = Xoshiro256pp::seeded(13);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            let expect = n as f64 / 10.0;
            assert!((c as f64 - expect).abs() < expect * 0.1, "bucket count {c} too far from {expect}");
        }
    }

    #[test]
    fn jump_streams_disjoint_prefixes() {
        let streams = Xoshiro256pp::streams(5, 4);
        let mut firsts: Vec<u64> = streams
            .into_iter()
            .map(|mut s| s.next_u64())
            .collect();
        firsts.sort_unstable();
        firsts.dedup();
        assert_eq!(firsts.len(), 4, "parallel streams should not collide");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::seeded(21);
        let mut xs: Vec<u32> = (0..1000).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(xs, (0..1000).collect::<Vec<_>>(), "shuffle should move elements");
    }
}
