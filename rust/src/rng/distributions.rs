//! Sampling distributions built on [`Xoshiro256pp`](super::Xoshiro256pp).
//!
//! The paper evaluates on uniform integers in [-1e9, +1e9]; real sorting
//! workloads also exercise skewed (Zipf), clustered (Gaussian), and
//! low-cardinality inputs, which our ablation benches use.

use super::Xoshiro256pp;

/// Standard-normal sample via Box–Muller (polar form avoided for simplicity;
/// the trig form is fine for data generation).
pub fn gaussian(rng: &mut Xoshiro256pp, mean: f64, stddev: f64) -> f64 {
    // Avoid log(0).
    let u1 = (1.0 - rng.next_f64()).max(f64::MIN_POSITIVE);
    let u2 = rng.next_f64();
    let r = (-2.0 * u1.ln()).sqrt();
    mean + stddev * r * (2.0 * std::f64::consts::PI * u2).cos()
}

/// Zipf(s, n) sampler over ranks {1..=n} using rejection-inversion
/// (Hörmann & Derflinger, 1996). Good for s in (0, ~5], n up to 2^62.
pub struct Zipf {
    n: u64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    dd: f64,
}

impl Zipf {
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n >= 1 && s > 0.0 && (s - 1.0).abs() > 1e-9, "Zipf needs n>=1, s>0, s != 1");
        let h = |x: f64| -> f64 { ((1.0 - s) * x.ln()).exp() / (1.0 - s) };
        let h_x1 = h(1.5) - 1.0f64.powf(-s);
        let h_n = h(n as f64 + 0.5);
        let dd = h(2.5) - 2.0f64.powf(-s) - h_x1;
        Zipf { n, s, h_x1, h_n, dd }
    }

    fn h_inv(&self, x: f64) -> f64 {
        ((1.0 - self.s) * x).powf(1.0 / (1.0 - self.s))
    }

    pub fn sample(&self, rng: &mut Xoshiro256pp) -> u64 {
        loop {
            let u = self.h_x1 + rng.next_f64() * (self.h_n - self.h_x1);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().max(1.0).min(self.n as f64);
            let h = |y: f64| ((1.0 - self.s) * y.ln()).exp() / (1.0 - self.s);
            if u >= h(k + 0.5) - (-self.s * k.ln()).exp() - self.dd {
                return k as u64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_moments() {
        let mut rng = Xoshiro256pp::seeded(31);
        let n = 100_000;
        let samples: Vec<f64> = (0..n).map(|_| gaussian(&mut rng, 10.0, 2.0)).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.05, "mean {mean}");
        assert!((var.sqrt() - 2.0).abs() < 0.05, "stddev {}", var.sqrt());
    }

    #[test]
    fn zipf_rank_one_most_frequent() {
        let z = Zipf::new(1000, 1.2);
        let mut rng = Xoshiro256pp::seeded(33);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..50_000 {
            let k = z.sample(&mut rng);
            assert!((1..=1000).contains(&k));
            *counts.entry(k).or_insert(0usize) += 1;
        }
        let c1 = counts.get(&1).copied().unwrap_or(0);
        let c2 = counts.get(&2).copied().unwrap_or(0);
        let c10 = counts.get(&10).copied().unwrap_or(0);
        assert!(c1 > c2, "rank 1 ({c1}) should beat rank 2 ({c2})");
        assert!(c1 > c10 * 2, "rank 1 ({c1}) should dominate rank 10 ({c10})");
    }

    #[test]
    fn zipf_respects_bounds() {
        let z = Zipf::new(5, 2.0);
        let mut rng = Xoshiro256pp::seeded(35);
        for _ in 0..10_000 {
            assert!((1..=5).contains(&z.sample(&mut rng)));
        }
    }
}
