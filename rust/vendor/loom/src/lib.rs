//! Offline shim of the [loom](https://crates.io/crates/loom) model checker.
//!
//! The real loom replaces `std::sync` primitives with instrumented doubles
//! and runs the test closure under **every** feasible thread interleaving
//! (bounded by a preemption budget), turning heisenbug hunts into exhaustive
//! proofs. This vendored stand-in keeps the *API surface* — `model()`,
//! `sync::*`, `thread`, `cell::UnsafeCell` with its `with`/`with_mut` access
//! protocol — but implements [`model`] as a bounded stress loop over the real
//! `std` primitives, because the build environment has no registry access.
//!
//! That trade-off is deliberate and documented at the call sites: the model
//! tests in `evosort` are written against loom's *stricter* API (all
//! `UnsafeCell` traffic goes through closures, no `const` atomics, no
//! `std::time` inside models), so pointing the workspace at the real
//! crates.io loom upgrades every test to an exhaustive interleaving search
//! with **zero source changes**:
//!
//! ```toml
//! # rust/Cargo.toml
//! loom = { version = "0.7", optional = true }   # instead of the path dep
//! ```
//!
//! The stress loop still catches real bugs (it runs each closure many times
//! with spawned OS threads and randomized-by-scheduler timing), it just
//! cannot prove their absence the way the real checker can.

/// Run `f` repeatedly as a bounded stress loop.
///
/// The real loom explores all interleavings; this shim re-runs the closure
/// `LOOM_SHIM_ITERS` times (default 64) and lets the OS scheduler provide
/// timing variation. Keep per-iteration work small, exactly as loom's own
/// documentation demands of model bodies.
pub fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    let iters: usize = std::env::var("LOOM_SHIM_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64);
    for _ in 0..iters {
        f();
    }
}

pub mod sync {
    pub use std::sync::{Arc, Barrier, Condvar, Mutex, MutexGuard, RwLock};

    pub mod atomic {
        pub use std::sync::atomic::{
            fence, AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering,
        };
    }
}

pub mod thread {
    pub use std::thread::{current, park, spawn, yield_now, Builder, JoinHandle};
}

pub mod hint {
    pub use std::hint::spin_loop;
}

pub mod cell {
    /// Mirror of `loom::cell::UnsafeCell`: all access goes through closures
    /// receiving raw pointers, which is what lets the real loom intercept and
    /// race-check every read and write. Here the closures lower to plain
    /// `std::cell::UnsafeCell::get` calls.
    #[derive(Debug, Default)]
    pub struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

    impl<T> UnsafeCell<T> {
        pub fn new(value: T) -> Self {
            Self(std::cell::UnsafeCell::new(value))
        }

        /// Run `f` with a shared raw pointer to the contents.
        pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
            f(self.0.get())
        }

        /// Run `f` with an exclusive raw pointer to the contents.
        pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
            f(self.0.get())
        }

        pub fn into_inner(self) -> T {
            self.0.into_inner()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::cell::UnsafeCell;
    use super::sync::atomic::{AtomicUsize, Ordering};
    use super::sync::Arc;

    #[test]
    fn model_runs_the_closure_multiple_times() {
        static RUNS: AtomicUsize = AtomicUsize::new(0);
        super::model(|| {
            RUNS.fetch_add(1, Ordering::Relaxed);
        });
        assert!(RUNS.load(Ordering::Relaxed) >= 2);
    }

    #[test]
    fn unsafe_cell_round_trips_through_closures() {
        let cell = UnsafeCell::new(41u32);
        // SAFETY: single-threaded test, no aliasing access in flight.
        cell.with_mut(|p| unsafe { *p += 1 });
        // SAFETY: as above.
        let read = cell.with(|p| unsafe { *p });
        assert_eq!(read, 42);
        assert_eq!(cell.into_inner(), 42);
    }

    #[test]
    fn model_closures_can_spawn_threads() {
        super::model(|| {
            let n = Arc::new(AtomicUsize::new(0));
            let n2 = Arc::clone(&n);
            let h = super::thread::spawn(move || n2.fetch_add(1, Ordering::SeqCst));
            n.fetch_add(1, Ordering::SeqCst);
            h.join().unwrap();
            assert_eq!(n.load(Ordering::SeqCst), 2);
        });
    }
}
