//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides the subset of the real `anyhow` API the workspace uses:
//!
//! * [`Error`] — a context-carrying error chain. `{e}` prints the outermost
//!   message, `{e:#}` prints the whole chain separated by `: `, and `{e:?}`
//!   prints the anyhow-style `Caused by:` report.
//! * [`Result`] with a defaulted error type.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` (for both
//!   std errors and [`Error`] itself) and on `Option`.
//! * The [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Swapping back to the real crate is a one-line `Cargo.toml` change; no
//! source edits are required for the surface used here.

use std::fmt;

/// A context-carrying error: an outermost message plus an optional cause
/// chain (outer → inner).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The messages of the chain, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().copied().unwrap_or("")
    }

    fn from_std(e: &(dyn std::error::Error + 'static)) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(Error { msg, source: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(first) = self.source.as_deref() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = Some(first);
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

// Relies on `Error` not implementing `std::error::Error`, exactly like the
// real anyhow crate, so the blanket impl cannot overlap the reflexive
// `From<Error> for Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

/// `anyhow::Result<T>` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::Error;

    /// Unifies "a std error" and "already an [`Error`]" for the blanket
    /// [`Context`](super::Context) impl (the same trick the real crate uses).
    pub trait StdError {
        fn into_anyhow(self) -> Error;
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_anyhow(self) -> Error {
            Error::from_std(&self)
        }
    }

    impl StdError for Error {
        fn into_anyhow(self) -> Error {
            self
        }
    }
}

/// Attach context to failures: implemented for `Result` (any error kind,
/// including [`Error`] itself) and `Option`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| ext::StdError::into_anyhow(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| ext::StdError::into_anyhow(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Result::<(), _>::Err(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("missing file"), "{dbg}");
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let e: Error = Result::<(), Error>::Err(anyhow!("inner {}", 7))
            .with_context(|| "outer")
            .unwrap_err();
        assert_eq!(e.chain(), vec!["outer", "inner 7"]);
        assert_eq!(e.root_cause(), "inner 7");

        let missing: Option<u32> = None;
        let e = missing.context("no value").unwrap_err();
        assert_eq!(format!("{e}"), "no value");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<String> {
            let s = std::str::from_utf8(&[0xFF])?;
            Ok(s.to_string())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative: -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big: 101");
    }
}
