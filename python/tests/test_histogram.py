"""L1 correctness: Pallas radix-histogram kernel vs the pure-jnp oracle."""

import numpy as np
import pytest

pytest.importorskip("jax", exc_type=ImportError, reason="jax unavailable: Pallas kernel tests skipped")
pytest.importorskip("hypothesis", exc_type=ImportError, reason="hypothesis unavailable: property tests skipped")

from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import histogram, ref


def _check(x_np: np.ndarray, shift: int) -> None:
    x = jnp.asarray(x_np, jnp.int32)
    got = np.asarray(histogram.block_histograms(x, shift))
    want = np.asarray(ref.ref_block_histograms(x, shift))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("shift", [0, 8, 16, 24])
def test_all_shifts(shift):
    rng = np.random.default_rng(7)
    _check(rng.integers(-(10**9), 10**9, size=(4, 512), dtype=np.int32), shift)


def test_counts_sum_to_block_size():
    rng = np.random.default_rng(9)
    x = rng.integers(-(2**31), 2**31 - 1, size=(3, 256), dtype=np.int32)
    h = np.asarray(histogram.block_histograms(jnp.asarray(x), 0))
    assert h.shape == (3, 256)
    np.testing.assert_array_equal(h.sum(axis=1), np.full(3, 256))


def test_known_histogram():
    # Bytes 0..3 each appearing a known number of times.
    x = np.array([[0] * 5 + [1] * 3 + [2] * 7 + [3] * 1], dtype=np.int32)
    h = np.asarray(histogram.block_histograms(jnp.asarray(x), 0))
    assert h[0, 0] == 5 and h[0, 1] == 3 and h[0, 2] == 7 and h[0, 3] == 1
    assert h[0, 4:].sum() == 0


def test_negative_values_logical_shift():
    # Negative ints must use *logical* shift semantics (sign bits land in the
    # top byte at shift 24), matching the rust radix pass exactly.
    x = np.array([[-1, -(2**31), 2**31 - 1, 0]], dtype=np.int32)
    _check(x, 24)
    _check(x, 0)


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=4),
    t=st.sampled_from([1, 16, 128, 1024]),
    shift=st.sampled_from([0, 8, 16, 24]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_sweep(b, t, shift, seed):
    rng = np.random.default_rng(seed)
    _check(rng.integers(np.iinfo(np.int32).min, np.iinfo(np.int32).max, (b, t), dtype=np.int32), shift)
