"""L1 correctness: Pallas bitonic tile sort vs the pure-jnp oracle.

This is the CORE correctness signal for the kernel layer: hypothesis sweeps
shapes and value regimes (including INT32_MIN/MAX sentinels the rust backend
pads with) and asserts exact equality against ``ref.ref_sort_tiles``.
"""

import numpy as np
import pytest

pytest.importorskip("jax", exc_type=ImportError, reason="jax unavailable: Pallas kernel tests skipped")
pytest.importorskip("hypothesis", exc_type=ImportError, reason="hypothesis unavailable: property tests skipped")

from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import bitonic, ref


def _check(x_np: np.ndarray) -> None:
    x = jnp.asarray(x_np, jnp.int32)
    got = np.asarray(bitonic.sort_tiles(x))
    want = np.asarray(ref.ref_sort_tiles(x))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("b,t", [(1, 1), (1, 2), (3, 8), (2, 64), (4, 256), (2, 1024)])
def test_shapes_random(b, t):
    rng = np.random.default_rng(42)
    _check(rng.integers(-(10**9), 10**9, size=(b, t), dtype=np.int32))


def test_extreme_values():
    x = np.array(
        [[np.iinfo(np.int32).max, np.iinfo(np.int32).min, 0, -1, 1, 2, -2, 7]],
        dtype=np.int32,
    )
    _check(x)


def test_all_equal():
    _check(np.full((3, 128), 42, dtype=np.int32))


def test_presorted_and_reversed():
    asc = np.arange(256, dtype=np.int32)[None, :]
    _check(asc)
    _check(asc[:, ::-1].copy())


def test_rows_independent():
    # Each row sorted independently — values must not leak across rows.
    x = np.stack([np.full(64, 5, np.int32), np.full(64, -5, np.int32)])
    got = np.asarray(bitonic.sort_tiles(jnp.asarray(x)))
    assert (got[0] == 5).all() and (got[1] == -5).all()


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=4),
    log_t=st.integers(min_value=0, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    regime=st.sampled_from(["full", "paper", "small", "dupes"]),
)
def test_hypothesis_sweep(b, log_t, seed, regime):
    t = 1 << log_t
    rng = np.random.default_rng(seed)
    if regime == "full":
        x = rng.integers(np.iinfo(np.int32).min, np.iinfo(np.int32).max, (b, t), dtype=np.int32)
    elif regime == "paper":
        x = rng.integers(-(10**9), 10**9, (b, t), dtype=np.int32)
    elif regime == "small":
        x = rng.integers(-3, 4, (b, t), dtype=np.int32)
    else:
        x = np.repeat(rng.integers(-10, 10, (b, max(t // 4, 1)), dtype=np.int32), 4, axis=1)[:, :t]
    _check(x)


def test_bitonic_1d_direct():
    # The network itself (outside pallas_call) on a known vector.
    x = jnp.asarray([5, 1, 4, 2, 8, 0, 3, 3], jnp.int32)
    got = np.asarray(bitonic.bitonic_sort_1d(x))
    np.testing.assert_array_equal(got, np.array([0, 1, 2, 3, 3, 4, 5, 8]))


def test_non_power_of_two_rejected():
    with pytest.raises(AssertionError):
        bitonic.sort_tiles(jnp.zeros((1, 24), jnp.int32))
