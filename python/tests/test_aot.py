"""AOT path: lowering produces parseable HLO text + a consistent manifest."""

import os

import pytest

pytest.importorskip("jax", exc_type=ImportError, reason="jax unavailable: AOT lowering layer skipped")

from compile import aot


def test_tile_sort_lowers_to_hlo_text():
    text = aot.lower_tile_sort(batch=2, tile=64)
    assert "HloModule" in text
    # Parameter shape must appear (s32[2,64]) — the rust loader feeds this.
    assert "s32[2,64]" in text


def test_radix_hist_lowers_to_hlo_text():
    text = aot.lower_radix_hist(batch=2, tile=64)
    assert "HloModule" in text
    assert "s32[2,64]" in text
    assert "s32[2,256]" in text


def test_emit_writes_artifacts_and_manifest(tmp_path):
    rows = aot.emit(str(tmp_path), batch=2, tile=32)
    assert {r[0] for r in rows} == {"tile_sort", "radix_hist"}
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == 2
    for line in manifest:
        kind, name, batch, tile = line.split()
        assert (tmp_path / name).exists()
        assert int(batch) == 2 and int(tile) == 32
        assert "HloModule" in (tmp_path / name).read_text()[:200]


def test_emit_is_deterministic(tmp_path):
    a = aot.lower_tile_sort(batch=2, tile=32)
    b = aot.lower_tile_sort(batch=2, tile=32)
    assert a == b
