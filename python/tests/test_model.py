"""L2 correctness: the composed JAX graphs (shapes, tuple convention, fusion
of both kernels in one module)."""

import numpy as np
import pytest

pytest.importorskip("jax", exc_type=ImportError, reason="jax unavailable: model graph tests skipped")

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def test_tile_sort_model_tuple_and_shape():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(-100, 100, (8, 64), dtype=np.int32))
    out = model.tile_sort_model(x)
    assert isinstance(out, tuple) and len(out) == 1
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref.ref_sort_tiles(x)))


def test_radix_histogram_model():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.integers(-(10**9), 10**9, (4, 128), dtype=np.int32))
    (h,) = model.radix_histogram_model(x, jnp.asarray([8], jnp.int32))
    assert h.shape == (4, 256)
    np.testing.assert_array_equal(np.asarray(h), np.asarray(ref.ref_block_histograms(x, 8)))


def test_fused_graph_consistency():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(-1000, 1000, (2, 256), dtype=np.int32))
    sorted_tiles, hists = model.tile_sort_then_histogram(x, jnp.asarray([0], jnp.int32))
    np.testing.assert_array_equal(np.asarray(sorted_tiles), np.asarray(ref.ref_sort_tiles(x)))
    # Sorting permutes within rows, so histograms equal those of the input.
    np.testing.assert_array_equal(
        np.asarray(hists), np.asarray(ref.ref_block_histograms(x, 0))
    )
