"""Pure-jnp oracles for the Pallas kernels — the correctness reference the
pytest suite checks every kernel against (no Pallas, no custom code paths).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BUCKETS = 256


def ref_sort_tiles(x: jnp.ndarray) -> jnp.ndarray:
    """Reference for ``bitonic.sort_tiles``: row-wise jnp.sort."""
    return jnp.sort(x, axis=1)


def ref_block_histograms(x: jnp.ndarray, shift) -> jnp.ndarray:
    """Reference for ``histogram.block_histograms``: row-wise bincount of the
    selected byte."""
    shift = jnp.asarray(shift, jnp.int32)
    byte = jax.lax.shift_right_logical(x.astype(jnp.int32), shift) & 0xFF

    def row_hist(row):
        return jnp.bincount(row, length=BUCKETS).astype(jnp.int32)

    return jax.vmap(row_hist)(byte)
