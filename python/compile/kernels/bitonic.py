"""Layer-1 Pallas kernel: bitonic sort of power-of-two tiles.

The paper's refined parallel mergesort insertion-sorts small base chunks on
the CPU. Insertion sort is inherently serial, so on a TPU-shaped target the
base-chunk sort is re-thought as a **bitonic comparator network**: every
stage is a full-tile compare-exchange expressible as reshapes + selects, so
it maps onto the VPU's (8, 128) vector lanes with no data-dependent control
flow and no gathers.

Partner exchange trick: for stride ``j``, the partner of index ``i`` is
``i ^ j``. Reshaping the tile to ``(-1, 2*j)`` and swapping its two halves
realises ``x[i ^ j]`` as a pure layout operation — no gather/scatter, which
the TPU vector unit dislikes.

The kernel is lowered with ``interpret=True`` (the CPU PJRT plugin cannot
execute Mosaic custom-calls); numerics are identical either way, and the
real-TPU resource estimate lives in ``DESIGN.md`` §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange(x: jnp.ndarray, k: int, j: int) -> jnp.ndarray:
    """One bitonic stage over a 1-D power-of-two array.

    ``k`` is the bitonic block size bit, ``j`` the partner stride.
    """
    n = x.shape[0]
    idx = jax.lax.iota(jnp.int32, n)
    # Partner values x[i ^ j] via reshape + half-swap (layout-only).
    xr = x.reshape(-1, 2 * j)
    xp = jnp.concatenate([xr[:, j:], xr[:, :j]], axis=1).reshape(n)
    asc = (idx & k) == 0        # ascending bitonic block
    lower = (idx & j) == 0      # i < partner
    take_min = asc == lower
    return jnp.where(take_min, jnp.minimum(x, xp), jnp.maximum(x, xp))


def bitonic_sort_1d(x: jnp.ndarray) -> jnp.ndarray:
    """Sort a 1-D power-of-two array ascending with a bitonic network."""
    n = x.shape[0]
    assert n & (n - 1) == 0 and n > 0, f"tile must be a power of two, got {n}"
    if n == 1:
        return x
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            x = _compare_exchange(x, k, j)
            j //= 2
        k *= 2
    return x


def _tile_sort_kernel(x_ref, o_ref):
    """Pallas kernel body: sort one (1, T) VMEM-resident tile."""
    tile = x_ref[...]
    o_ref[...] = bitonic_sort_1d(tile.reshape(-1)).reshape(tile.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_tiles(x: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """Sort each row of an (B, T) int32 array independently.

    BlockSpec streams one (1, T) tile per grid step HBM -> VMEM; with
    T = 1024 the live footprint is ~3 x 4 KiB, far below the ~16 MiB VMEM
    budget (see DESIGN.md §Perf).
    """
    b, t = x.shape
    assert t & (t - 1) == 0, f"tile width must be a power of two, got {t}"
    return pl.pallas_call(
        _tile_sort_kernel,
        out_shape=jax.ShapeDtypeStruct((b, t), x.dtype),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, t), lambda i: (i, 0)),
        interpret=interpret,
    )(x)
