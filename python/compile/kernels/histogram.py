"""Layer-1 Pallas kernel: per-block byte histograms for the LSD radix sort.

The paper's radix passes build thread-local 256-bin histograms of one key
byte per block (Algorithm 4, line 5). A CPU builds them with data-dependent
increments (``hist[byte] += 1``); on a TPU-shaped target scatters are
hostile, so the count is re-expressed as a **one-hot reduction**: compare the
byte lane against ``iota(256)`` and sum the boolean matrix over the block
axis — a dense, branch-free VPU reduction.

The rust coordinator performs the global-prefix-sum reduction across block
histograms, mirroring the paper's "reduce to global histogram" step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BUCKETS = 256


def _hist_kernel(x_ref, shift_ref, o_ref):
    """Histogram of ((x >> shift) & 0xFF) for one (1, T) block."""
    x = x_ref[...].reshape(-1).astype(jnp.int32)
    shift = shift_ref[0]
    byte = jax.lax.shift_right_logical(x, shift) & 0xFF
    # One-hot reduction: (T, 1) == (1, 256) -> (T, 256) bools -> sum -> (256,)
    onehot = byte[:, None] == jax.lax.iota(jnp.int32, BUCKETS)[None, :]
    o_ref[...] = jnp.sum(onehot.astype(jnp.int32), axis=0).reshape(1, BUCKETS)


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_histograms(
    x: jnp.ndarray, shift: jnp.ndarray, *, interpret: bool = True
) -> jnp.ndarray:
    """Per-row byte histograms: (B, T) int32, scalar shift -> (B, 256) int32."""
    b, t = x.shape
    shift = jnp.asarray(shift, jnp.int32).reshape((1,))
    return pl.pallas_call(
        _hist_kernel,
        out_shape=jax.ShapeDtypeStruct((b, BUCKETS), jnp.int32),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, BUCKETS), lambda i: (i, 0)),
        interpret=interpret,
    )(x, shift)
