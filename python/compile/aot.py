"""AOT lowering: JAX (L2) -> HLO text artifacts for the rust PJRT runtime.

HLO **text** is the interchange format, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    python -m compile.aot --out-dir ../artifacts [--tile 1024] [--batch 32]

Emits:

* ``tile_sort_b{B}_t{T}.hlo.txt``   — the bitonic tile-sort executable
* ``radix_hist_b{B}_t{T}.hlo.txt``  — the histogram executable
* ``manifest.txt``                  — one line per artifact:
  ``<kind> <file> <batch> <tile>`` (parsed by rust/src/runtime/artifacts.rs)
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

DEFAULT_TILE = 1024
DEFAULT_BATCH = 32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_tile_sort(batch: int, tile: int) -> str:
    spec = jax.ShapeDtypeStruct((batch, tile), jnp.int32)
    return to_hlo_text(jax.jit(model.tile_sort_model).lower(spec))


def lower_radix_hist(batch: int, tile: int) -> str:
    xspec = jax.ShapeDtypeStruct((batch, tile), jnp.int32)
    sspec = jax.ShapeDtypeStruct((1,), jnp.int32)
    return to_hlo_text(jax.jit(model.radix_histogram_model).lower(xspec, sspec))


def emit(out_dir: str, batch: int, tile: int) -> list[tuple[str, str, int, int]]:
    """Lower both models, write artifacts + manifest, return manifest rows."""
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    for kind, lower in (("tile_sort", lower_tile_sort), ("radix_hist", lower_radix_hist)):
        name = f"{kind}_b{batch}_t{tile}.hlo.txt"
        path = os.path.join(out_dir, name)
        text = lower(batch, tile)
        with open(path, "w") as f:
            f.write(text)
        rows.append((kind, name, batch, tile))
        print(f"wrote {path} ({len(text)} chars)")
    manifest = os.path.join(out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        for kind, name, b, t in rows:
            f.write(f"{kind} {name} {b} {t}\n")
    print(f"wrote {manifest}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--tile", type=int, default=DEFAULT_TILE)
    ap.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    args = ap.parse_args()
    assert args.tile & (args.tile - 1) == 0, "--tile must be a power of two"
    emit(args.out_dir, args.batch, args.tile)


if __name__ == "__main__":
    main()
