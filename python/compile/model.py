"""Layer-2 JAX compute graphs, composed from the Layer-1 Pallas kernels.

Two graphs are AOT-lowered to HLO text for the rust runtime:

* ``tile_sort_model``     — (B, T) int32 -> (B, T) int32: every row sorted
  (the Pallas bitonic network). The rust adaptive dispatcher uses this as
  the ``A_code = 5`` tile-sort backend and merges the sorted runs itself.
* ``radix_histogram_model`` — (B, T) int32 + scalar shift -> (B, 256) int32:
  per-block byte histograms (the Pallas one-hot reduction kernel). The rust
  radix path can offload histogram building through this artifact.

Python never runs on the request path: these functions exist to be lowered
once by ``aot.py``.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels import bitonic, histogram


def tile_sort_model(x: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Sort each (power-of-two wide) row of ``x`` ascending.

    Returns a 1-tuple: the HLO interchange convention is ``return_tuple=True``
    (see aot.py), matching the rust loader's ``to_tuple1`` unwrap.
    """
    return (bitonic.sort_tiles(x),)


def radix_histogram_model(x: jnp.ndarray, shift: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Per-row 256-bin histograms of byte ``(x >> shift) & 0xFF``."""
    return (histogram.block_histograms(x, shift),)


def tile_sort_then_histogram(x: jnp.ndarray, shift: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused graph used by tests: sorted tiles and their byte histograms.

    Exercises kernel composition inside one lowered module (XLA fuses the
    surrounding element-wise ops; see EXPERIMENTS.md §Perf L2).
    """
    sorted_tiles = bitonic.sort_tiles(x)
    hists = histogram.block_histograms(sorted_tiles, shift)
    return (sorted_tiles, hists)
