"""Pytest bootstrap for the Pallas/AOT layer.

Makes the ``compile`` package importable when the suite is launched from the
repository root (``python -m pytest python/tests -q``), regardless of
pytest's rootdir heuristics.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
