//! `cargo xtask lint` — the repo-native invariant linter.
//!
//! The compiler proves types; this tool proves the cross-file naming and
//! protocol invariants nothing in rustc's lattice can see:
//!
//! 1. **Metric-name registry** — production code never spells a metric name
//!    as a string literal; every series name flows through
//!    `coordinator::metrics::names`. (Test modules may use literals — that
//!    is what pins the registry's values.)
//! 2. **Phase table coherence** — the `obs::event::Phase` enum, the
//!    `names::KERNEL_PHASES` span-name table, and the phase keys in
//!    committed `BENCH_*.json` reports all describe the same set of kernel
//!    phases (dense discriminants, `kernel.`-prefixed names, one registry
//!    const per variant).
//! 3. **Frame-tag discipline** — the shard protocol's `TAG_*` constants are
//!    unique and dense, so a new frame type cannot shadow or skip a wire
//!    tag.
//! 4. **Knob parity** — every `[service]` config key is mirrored by a serve
//!    CLI flag and documented in the README knob table, and vice versa.
//! 5. **Sanctioned construction** — `ServiceConfig` struct literals exist
//!    only in `coordinator/service.rs`; everything else goes through the
//!    builder, so adding a field cannot silently default at stray sites.
//! 6. **Bench report schema** — committed `BENCH_*.json` files carry a known
//!    `schema` version, and their Prometheus-facing names in the README
//!    match the registry's sanitized forms.
//!
//! Exit status 0 = clean, 1 = violations (printed one per line), 2 = usage.
//! Pure `std`: the checks are line/token-oriented text analysis over a
//! comment-and-string-aware mask of the sources, so no `syn` stack is
//! needed and the tool builds offline.

#![forbid(unsafe_code)]

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => {
            let violations = run_lint(&repo_root());
            if violations.is_empty() {
                println!("xtask lint: all invariants hold");
                ExitCode::SUCCESS
            } else {
                for v in &violations {
                    eprintln!("{v}");
                }
                eprintln!("xtask lint: {} violation(s)", violations.len());
                ExitCode::FAILURE
            }
        }
        _ => {
            eprintln!("usage: cargo xtask lint");
            ExitCode::from(2)
        }
    }
}

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("xtask sits in the workspace").into()
}

/// Run every check against the real tree; returns all violations.
fn run_lint(root: &Path) -> Vec<String> {
    let mut v = Vec::new();
    let read = |rel: &str| -> String {
        std::fs::read_to_string(root.join(rel))
            .unwrap_or_else(|e| panic!("xtask lint: cannot read {rel}: {e}"))
    };

    // Per-file source rules over the crate, the examples, and the benches.
    let mut files: Vec<PathBuf> = Vec::new();
    rs_files(&root.join("rust/src"), &mut files);
    rs_files(&root.join("rust/benches"), &mut files);
    rs_files(&root.join("examples"), &mut files);
    files.sort();
    for f in &files {
        let rel = f.strip_prefix(root).unwrap_or(f).display().to_string();
        let text = std::fs::read_to_string(f)
            .unwrap_or_else(|e| panic!("xtask lint: cannot read {rel}: {e}"));
        if !rel.ends_with("coordinator/metrics/names.rs") {
            v.extend(find_metric_literals(&rel, &text));
        }
        if !rel.ends_with("coordinator/service.rs") {
            v.extend(find_service_config_literals(&rel, &text));
        }
    }

    let names_src = read("rust/src/coordinator/metrics/names.rs");
    let event_src = read("rust/src/obs/event.rs");
    v.extend(check_phase_registry(&names_src, &event_src));
    v.extend(check_frame_tags(
        "rust/src/coordinator/shard/protocol.rs",
        &read("rust/src/coordinator/shard/protocol.rs"),
    ));

    let readme = read("README.md");
    let cli_all = read("rust/src/cli/mod.rs") + &read("rust/src/cli/commands.rs");
    v.extend(check_service_knob_parity(&read("rust/src/config/run.rs"), &readme, &cli_all));
    v.extend(check_readme_metric_names(&readme, &registry_prometheus_forms(&names_src)));

    // Committed bench reports: known schema, phase keys from the registry.
    let phases = parse_str_array(&names_src, "KERNEL_PHASES").unwrap_or_default();
    let mut reports: Vec<PathBuf> = std::fs::read_dir(root)
        .into_iter()
        .flatten()
        .flatten()
        .map(|e| e.path())
        .filter(|p| {
            let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
            name.starts_with("BENCH_") && name.ends_with(".json")
        })
        .collect();
    reports.sort();
    for r in &reports {
        let rel = r.strip_prefix(root).unwrap_or(r).display().to_string();
        let text = std::fs::read_to_string(r)
            .unwrap_or_else(|e| panic!("xtask lint: cannot read {rel}: {e}"));
        v.extend(check_bench_report(&rel, &text, &phases));
    }
    v
}

fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for e in entries.flatten() {
        let p = e.path();
        if p.is_dir() {
            // The vendored shims are excluded from first-party rules.
            if p.file_name().is_some_and(|n| n == "vendor") {
                continue;
            }
            rs_files(&p, out);
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
}

// ---------------------------------------------------------------------------
// Source masking: comment- and string-aware views of a Rust file.
// ---------------------------------------------------------------------------

/// Byte-preserving masks of one source file. Offsets (and therefore line
/// numbers) are identical to the original in every view.
struct Mask {
    /// Comments blanked to spaces; string contents kept.
    code: String,
    /// Comments *and* string/char contents blanked; quotes kept. Safe for
    /// brace matching and identifier scans.
    bare: String,
}

impl Mask {
    fn of(src: &str) -> Mask {
        let b = src.as_bytes();
        let mut code = Vec::with_capacity(b.len());
        let mut bare = Vec::with_capacity(b.len());
        let blank = |v: &mut Vec<u8>, c: u8| v.push(if c == b'\n' { b'\n' } else { b' ' });
        let mut i = 0;
        while i < b.len() {
            let c = b[i];
            match c {
                b'/' if b.get(i + 1) == Some(&b'/') => {
                    while i < b.len() && b[i] != b'\n' {
                        blank(&mut code, b[i]);
                        blank(&mut bare, b[i]);
                        i += 1;
                    }
                }
                b'/' if b.get(i + 1) == Some(&b'*') => {
                    let mut depth = 0usize;
                    while i < b.len() {
                        if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                            depth += 1;
                            blank(&mut code, b[i]);
                            blank(&mut bare, b[i]);
                            i += 1;
                        } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                            depth -= 1;
                            blank(&mut code, b[i]);
                            blank(&mut bare, b[i]);
                            blank(&mut code, b[i + 1]);
                            blank(&mut bare, b[i + 1]);
                            i += 2;
                            if depth == 0 {
                                break;
                            }
                            continue;
                        }
                        blank(&mut code, b[i]);
                        blank(&mut bare, b[i]);
                        i += 1;
                    }
                }
                b'r' if matches!(b.get(i + 1), Some(b'"') | Some(b'#'))
                    && !prev_is_ident(b, i) =>
                {
                    // Raw string r"…" / r#"…"# (any hash count).
                    let mut j = i + 1;
                    let mut hashes = 0;
                    while b.get(j) == Some(&b'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if b.get(j) != Some(&b'"') {
                        // `r#ident` raw identifier, not a string.
                        code.push(c);
                        bare.push(c);
                        i += 1;
                        continue;
                    }
                    for &byte in &b[i..=j] {
                        code.push(byte);
                        bare.push(byte);
                    }
                    i = j + 1;
                    loop {
                        if i >= b.len() {
                            break;
                        }
                        if b[i] == b'"' && (0..hashes).all(|h| b.get(i + 1 + h) == Some(&b'#')) {
                            for &byte in &b[i..=i + hashes] {
                                code.push(byte);
                                bare.push(byte);
                            }
                            i += hashes + 1;
                            break;
                        }
                        code.push(b[i]);
                        blank(&mut bare, b[i]);
                        i += 1;
                    }
                }
                b'"' => {
                    code.push(c);
                    bare.push(c);
                    i += 1;
                    while i < b.len() {
                        if b[i] == b'\\' {
                            code.push(b[i]);
                            blank(&mut bare, b[i]);
                            if i + 1 < b.len() {
                                code.push(b[i + 1]);
                                blank(&mut bare, b[i + 1]);
                            }
                            i += 2;
                            continue;
                        }
                        if b[i] == b'"' {
                            code.push(b[i]);
                            bare.push(b[i]);
                            i += 1;
                            break;
                        }
                        code.push(b[i]);
                        blank(&mut bare, b[i]);
                        i += 1;
                    }
                }
                b'\'' => {
                    // Char literal vs lifetime: 'x' / '\…' are literals,
                    // anything else ('a in types) is a lifetime tick.
                    if b.get(i + 1) == Some(&b'\\') {
                        code.push(c);
                        bare.push(c);
                        i += 1;
                        while i < b.len() && b[i] != b'\'' {
                            code.push(b[i]);
                            blank(&mut bare, b[i]);
                            if b[i] == b'\\' && i + 1 < b.len() {
                                code.push(b[i + 1]);
                                blank(&mut bare, b[i + 1]);
                                i += 2;
                            } else {
                                i += 1;
                            }
                        }
                        if i < b.len() {
                            code.push(b'\'');
                            bare.push(b'\'');
                            i += 1;
                        }
                    } else if b.get(i + 2) == Some(&b'\'') {
                        code.push(c);
                        bare.push(c);
                        code.push(b[i + 1]);
                        blank(&mut bare, b[i + 1]);
                        code.push(b'\'');
                        bare.push(b'\'');
                        i += 3;
                    } else {
                        code.push(c);
                        bare.push(c);
                        i += 1;
                    }
                }
                _ => {
                    code.push(c);
                    bare.push(c);
                    i += 1;
                }
            }
        }
        let fix = |v: Vec<u8>| String::from_utf8(v).expect("mask preserves UTF-8");
        Mask { code: fix(code), bare: fix(bare) }
    }

    /// The `code` view with every `#[cfg(test)]` / `#[cfg(all(test, …))]`
    /// module body blanked out (test code may use metric-name literals —
    /// that is how the registry's values get pinned).
    fn code_without_test_mods(&self) -> String {
        let mut out = self.code.clone().into_bytes();
        let bare = self.bare.as_bytes();
        for needle in ["#[cfg(test)]", "#[cfg(all(test"] {
            let mut from = 0;
            while let Some(p) = self.bare[from..].find(needle) {
                let start = from + p;
                // Find the block the attribute guards and blank it wholly.
                let Some(open_rel) = self.bare[start..].find('{') else { break };
                let open = start + open_rel;
                let mut depth = 0usize;
                let mut end = bare.len();
                for (k, &c) in bare.iter().enumerate().skip(open) {
                    if c == b'{' {
                        depth += 1;
                    } else if c == b'}' {
                        depth -= 1;
                        if depth == 0 {
                            end = k + 1;
                            break;
                        }
                    }
                }
                for item in out.iter_mut().take(end).skip(start) {
                    if *item != b'\n' {
                        *item = b' ';
                    }
                }
                from = end;
            }
        }
        String::from_utf8(out).expect("blanking preserves UTF-8")
    }
}

fn prev_is_ident(b: &[u8], i: usize) -> bool {
    i > 0 && (b[i - 1].is_ascii_alphanumeric() || b[i - 1] == b'_')
}

fn line_of(s: &str, byte_pos: usize) -> usize {
    s.as_bytes()[..byte_pos].iter().filter(|&&c| c == b'\n').count() + 1
}

// ---------------------------------------------------------------------------
// Rule 1: no metric-name string literals in production code.
// ---------------------------------------------------------------------------

/// Metrics-API calls whose first argument names a series. A string literal
/// in that position bypasses the registry.
const METRIC_CALLS: [&str; 10] = [
    ".incr(\"",
    ".add(\"",
    ".observe(\"",
    ".observe_sample(\"",
    ".set_gauge(\"",
    ".counter(\"",
    ".counter_ratio(\"",
    ".gauge(\"",
    ".latency(\"",
    ".percentile(\"",
];

fn find_metric_literals(label: &str, src: &str) -> Vec<String> {
    let code = Mask::of(src).code_without_test_mods();
    let mut out = Vec::new();
    for pat in METRIC_CALLS {
        let mut from = 0;
        while let Some(p) = code[from..].find(pat) {
            let at = from + p;
            out.push(format!(
                "{label}:{}: metric name spelled as a literal ({}\"…\")) — route it through \
                 coordinator::metrics::names",
                line_of(&code, at),
                &pat[..pat.len() - 1],
            ));
            from = at + pat.len();
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 2: Phase enum ↔ KERNEL_PHASES span table coherence.
// ---------------------------------------------------------------------------

/// Parse `pub const NAME: [&str; N] = [..];` → entries. Elements may be
/// string literals or idents of `pub const X: &str = "…";` constants
/// declared in the same file (the registry's style). Returns None if the
/// array is absent; unresolvable idents resolve to `"<ident>?"` so the
/// caller's comparisons fail loudly instead of silently shrinking.
fn parse_str_array(src: &str, name: &str) -> Option<Vec<String>> {
    let needle = format!("pub const {name}: [&str; ");
    let start = src.find(&needle)?;
    let open = start + src[start..].find('[')?;
    let close_ty = open + src[open..].find(']')?;
    let body_open = close_ty + src[close_ty..].find('[')?;
    let body_close = body_open + src[body_open..].find(']')?;
    let mut entries = Vec::new();
    for tok in src[body_open + 1..body_close].split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue; // trailing comma
        }
        if let Some(lit) = tok.strip_prefix('"') {
            entries.push(lit.trim_end_matches('"').to_string());
        } else {
            let decl = format!("pub const {tok}: &str = \"");
            match src.find(&decl) {
                Some(p) => {
                    let val = &src[p + decl.len()..];
                    entries.push(val[..val.find('"')?].to_string());
                }
                None => entries.push(format!("{tok}?")),
            }
        }
    }
    Some(entries)
}

fn check_phase_registry(names_src: &str, event_src: &str) -> Vec<String> {
    let mut v = Vec::new();
    let names_label = "rust/src/coordinator/metrics/names.rs";
    let event_label = "rust/src/obs/event.rs";

    let Some(phases) = parse_str_array(names_src, "KERNEL_PHASES") else {
        return vec![format!("{names_label}: KERNEL_PHASES table not found")];
    };
    let declared: Option<usize> = names_src
        .split("pub const KERNEL_PHASES: [&str; ")
        .nth(1)
        .and_then(|r| r.split(']').next())
        .and_then(|n| n.trim().parse().ok());
    if declared != Some(phases.len()) {
        v.push(format!(
            "{names_label}: KERNEL_PHASES declared arity {declared:?} != {} entries",
            phases.len()
        ));
    }
    let unique: BTreeSet<&String> = phases.iter().collect();
    if unique.len() != phases.len() {
        v.push(format!("{names_label}: KERNEL_PHASES entries are not unique"));
    }
    for p in &phases {
        if !p.starts_with("kernel.") {
            v.push(format!("{names_label}: phase span {p:?} must start with \"kernel.\""));
        }
    }

    // Phase enum: dense discriminants 0..N, COUNT == N, one registry const
    // per variant in metric_name().
    let bare = Mask::of(event_src).bare;
    let mut discs = Vec::new();
    if let Some(enum_start) = bare.find("pub enum Phase") {
        if let Some(open_rel) = bare[enum_start..].find('{') {
            let open = enum_start + open_rel;
            if let Some(close_rel) = bare[open..].find('}') {
                for line in bare[open + 1..open + close_rel].lines() {
                    let t = line.trim().trim_end_matches(',');
                    if let Some((ident, disc)) = t.split_once('=') {
                        let ident = ident.trim();
                        if ident.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
                            match disc.trim().parse::<usize>() {
                                Ok(d) => discs.push(d),
                                Err(_) => v.push(format!(
                                    "{event_label}: Phase::{ident} needs an explicit integer \
                                     discriminant"
                                )),
                            }
                        }
                    }
                }
            }
        }
    }
    if discs.is_empty() {
        v.push(format!("{event_label}: Phase enum with explicit discriminants not found"));
        return v;
    }
    let expect: Vec<usize> = (0..discs.len()).collect();
    if discs != expect {
        v.push(format!("{event_label}: Phase discriminants {discs:?} are not dense from 0"));
    }
    if discs.len() != phases.len() {
        v.push(format!(
            "{event_label}: Phase has {} variants but KERNEL_PHASES lists {}",
            discs.len(),
            phases.len()
        ));
    }
    let count: Option<usize> = event_src
        .split("pub const COUNT: usize = ")
        .nth(1)
        .and_then(|r| r.split(';').next())
        .and_then(|n| n.trim().parse().ok());
    if count != Some(discs.len()) {
        v.push(format!(
            "{event_label}: Phase::COUNT is {count:?} but the enum has {} variants",
            discs.len()
        ));
    }
    let mut kernel_consts: BTreeSet<String> = BTreeSet::new();
    let event_code = Mask::of(event_src).code; // comments may cite consts freely
    let mut rest = event_code.as_str();
    while let Some(p) = rest.find("names::KERNEL_") {
        let tail = &rest[p + "names::".len()..];
        let end =
            tail.find(|c: char| !(c.is_ascii_alphanumeric() || c == '_')).unwrap_or(tail.len());
        kernel_consts.insert(tail[..end].to_string());
        rest = &tail[end..];
    }
    kernel_consts.remove("KERNEL_PHASES");
    if kernel_consts.len() != discs.len() {
        v.push(format!(
            "{event_label}: metric_name() references {} distinct names::KERNEL_* consts for {} \
             variants",
            kernel_consts.len(),
            discs.len()
        ));
    }
    v
}

// ---------------------------------------------------------------------------
// Rule 3: protocol frame tags unique and dense.
// ---------------------------------------------------------------------------

fn check_frame_tags(label: &str, src: &str) -> Vec<String> {
    let mut v = Vec::new();
    let mut tags: Vec<(String, u64)> = Vec::new();
    for line in Mask::of(src).bare.lines() {
        let t = line.trim();
        let t = t.strip_prefix("pub ").unwrap_or(t);
        let Some(rest) = t.strip_prefix("const TAG_") else { continue };
        let Some((name, rhs)) = rest.split_once(':') else { continue };
        let Some(value) = rhs.split('=').nth(1) else { continue };
        match value.trim().trim_end_matches(';').parse::<u64>() {
            Ok(n) => tags.push((format!("TAG_{name}"), n)),
            Err(_) => v.push(format!("{label}: cannot parse tag value in {t:?}")),
        }
    }
    if tags.is_empty() {
        return vec![format!("{label}: no TAG_* frame tags found")];
    }
    let mut seen = BTreeSet::new();
    for (name, n) in &tags {
        if !seen.insert(n) {
            v.push(format!("{label}: duplicate frame tag value {n} at {name}"));
        }
    }
    let max = tags.iter().map(|&(_, n)| n).max().unwrap_or(0);
    let want: BTreeSet<u64> = (1..=max).collect();
    let missing: Vec<u64> = want.difference(&seen).copied().collect();
    if !missing.is_empty() {
        v.push(format!("{label}: frame tags are not dense — missing {missing:?} below {max}"));
    }
    if seen.contains(&0) {
        v.push(format!("{label}: tag 0 is reserved (uninitialised frame guard)"));
    }
    v
}

// ---------------------------------------------------------------------------
// Rule 4: [service] keys ↔ serve CLI flags ↔ README knob table.
// ---------------------------------------------------------------------------

fn parse_service_keys(run_src: &str) -> BTreeSet<String> {
    // Every typed lookup is `doc.<kind>("service", "<key>", …)`.
    let mut keys = BTreeSet::new();
    let code = &Mask::of(run_src).code;
    let mut rest = code.as_str();
    while let Some(p) = rest.find("\"service\", \"") {
        let after = &rest[p + "\"service\", \"".len()..];
        if let Some(q) = after.find('"') {
            keys.insert(after[..q].to_string());
            rest = &after[q..];
        } else {
            break;
        }
    }
    keys
}

fn parse_readme_knob_keys(readme: &str) -> BTreeSet<String> {
    let mut keys = BTreeSet::new();
    let mut in_table = false;
    for line in readme.lines() {
        let t = line.trim();
        if t.starts_with("| Key | Default |") {
            in_table = true;
            continue;
        }
        if in_table {
            if !t.starts_with('|') {
                in_table = false;
                continue;
            }
            if t.starts_with("|---") {
                continue;
            }
            if let Some(cell) = t.trim_start_matches('|').split('|').next() {
                let key = cell.trim().trim_matches('`');
                if !key.is_empty() {
                    keys.insert(key.to_string());
                }
            }
        }
    }
    keys
}

fn check_service_knob_parity(run_src: &str, readme: &str, cli_src: &str) -> Vec<String> {
    let mut v = Vec::new();
    let keys = parse_service_keys(run_src);
    if keys.is_empty() {
        return vec![
            "rust/src/config/run.rs: no [service] keys found (lookup pattern drifted?)".into(),
        ];
    }
    let readme_keys = parse_readme_knob_keys(readme);
    if readme_keys.is_empty() {
        return vec!["README.md: `| Key | Default |` service knob table not found".into()];
    }
    for key in &keys {
        if !readme_keys.contains(key) {
            v.push(format!(
                "README.md: [service] key `{key}` is missing from the service knob table"
            ));
        }
        let flag = key.replace('_', "-");
        if !cli_src.contains(&format!("\"{flag}\"")) && !cli_src.contains(&format!("--{flag}")) {
            v.push(format!(
                "rust/src/cli: [service] key `{key}` has no matching `--{flag}` serve flag"
            ));
        }
    }
    for key in &readme_keys {
        if !keys.contains(key) {
            v.push(format!(
                "README.md: knob table lists `{key}` which is not a [service] key in \
                 config/run.rs"
            ));
        }
    }
    v
}

// ---------------------------------------------------------------------------
// Rule 5: ServiceConfig struct literals only in coordinator/service.rs.
// ---------------------------------------------------------------------------

fn find_service_config_literals(label: &str, src: &str) -> Vec<String> {
    // Applies to tests too: the builder (`ServiceConfig::sized` + `with_*`)
    // is the only sanctioned construction outside service.rs.
    let bare = &Mask::of(src).bare;
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = bare[from..].find("ServiceConfig") {
        let at = from + p;
        from = at + "ServiceConfig".len();
        if prev_is_ident(bare.as_bytes(), at) {
            continue; // ShardWorkerServiceConfig etc.
        }
        // Type positions (`-> ServiceConfig {`, `impl ServiceConfig {`,
        // `impl Default for ServiceConfig {`) are not struct literals.
        let before = bare[..at].trim_end();
        if before.ends_with("->") || before.ends_with("impl") || before.ends_with("for") {
            continue;
        }
        let tail = bare[from..].trim_start();
        if tail.starts_with('{') {
            out.push(format!(
                "{label}:{}: `ServiceConfig {{ … }}` struct literal — construct through \
                 ServiceConfig::sized()/with_*() so new fields cannot silently default here",
                line_of(bare, at),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 6: bench report schema + README Prometheus names.
// ---------------------------------------------------------------------------

const KNOWN_BENCH_SCHEMAS: [&str; 2] = ["evosort-bench-v1", "evosort-bench-v2"];

fn check_bench_report(label: &str, json: &str, kernel_phases: &[String]) -> Vec<String> {
    let mut v = Vec::new();
    let schema = json
        .split("\"schema\"")
        .nth(1)
        .and_then(|r| r.split('"').nth(1))
        .map(str::to_string);
    match schema {
        None => v.push(format!("{label}: no \"schema\" field")),
        Some(s) if !KNOWN_BENCH_SCHEMAS.contains(&s.as_str()) => {
            v.push(format!("{label}: unknown schema {s:?} (known: {KNOWN_BENCH_SCHEMAS:?})"));
        }
        Some(_) => {}
    }
    // Any per-phase timing keys must come from the span-name table.
    let mut rest = json;
    while let Some(p) = rest.find("\"phases\"") {
        let after = &rest[p + "\"phases\"".len()..];
        let Some(open) = after.find('{') else { break };
        let Some(close) = after[open..].find('}') else { break };
        let body = &after[open + 1..open + close];
        let mut b = body;
        while let Some(q) = b.find('"') {
            let tail = &b[q + 1..];
            let Some(q2) = tail.find('"') else { break };
            let key = &tail[..q2];
            let after_key = tail[q2 + 1..].trim_start();
            if after_key.starts_with(':') && !kernel_phases.iter().any(|k| k == key) {
                v.push(format!(
                    "{label}: phase key {key:?} is not in names::KERNEL_PHASES"
                ));
            }
            b = &tail[q2 + 1..];
        }
        rest = &after[open + close..];
    }
    v
}

/// All static registry names, in their Prometheus-sanitized (`evosort_*`)
/// forms — what the README metrics table is allowed to mention.
fn registry_prometheus_forms(names_src: &str) -> BTreeSet<String> {
    let mut forms = BTreeSet::new();
    let code = &Mask::of(names_src).code;
    let mut rest = code.as_str();
    while let Some(p) = rest.find(": &str = \"") {
        let after = &rest[p + ": &str = \"".len()..];
        let Some(q) = after.find('"') else { break };
        let name = &after[..q];
        if !name.contains("{}") {
            forms.insert(prometheus_form(name));
        }
        rest = &after[q..];
    }
    forms
}

/// Mirror of `metrics::prometheus_name` (kept in lockstep by the metrics
/// unit tests pinning the same examples).
fn prometheus_form(name: &str) -> String {
    let mut out = String::from("evosort_");
    out.extend(name.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }));
    out
}

fn check_readme_metric_names(readme: &str, registry: &BTreeSet<String>) -> Vec<String> {
    let mut v = Vec::new();
    for (idx, line) in readme.lines().enumerate() {
        if !line.trim_start().starts_with('|') {
            continue;
        }
        let mut rest = line;
        while let Some(p) = rest.find("`evosort_") {
            let token_start = &rest[p + 1..];
            let Some(close) = token_start.find('`') else { break };
            let token = &token_start[..close];
            // Pattern rows (`evosort_kernel_<kernel>_<phase>`) are schemas,
            // not literal series names.
            if !token.contains('<') && !registry.contains(token) {
                v.push(format!(
                    "README.md:{}: metrics table names {token:?} which no registry entry \
                     sanitizes to",
                    idx + 1
                ));
            }
            rest = &token_start[close..];
        }
    }
    v
}

// ---------------------------------------------------------------------------
// Fixture tests: each rule must catch a seeded violation of its class and
// stay quiet on the conforming shape.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_literal_in_production_code_is_caught() {
        let bad = r#"
            fn publish(m: &Metrics) {
                m.incr("jobs.completed");
                m.set_gauge("router.queue_depth", 3.0);
            }
        "#;
        let hits = find_metric_literals("fixture.rs", bad);
        assert_eq!(hits.len(), 2, "{hits:?}");
        assert!(hits[0].contains("fixture.rs:3"));
    }

    #[test]
    fn metric_literal_in_tests_comments_or_strings_is_allowed() {
        let ok = r#"
            fn publish(m: &Metrics) {
                m.incr(names::JOBS_COMPLETED);
                // a comment may say m.incr("jobs.completed") freely
                let msg = "call m.incr(\"jobs.completed\") here";
            }
            #[cfg(test)]
            mod tests {
                fn pins_registry(m: &Metrics) {
                    m.incr("jobs.completed");
                    assert_eq!(m.counter("jobs.completed"), 1);
                }
            }
        "#;
        assert!(find_metric_literals("fixture.rs", ok).is_empty());
        let gated = r#"
            #[cfg(all(test, feature = "loom"))]
            mod loom_tests {
                fn pins(m: &Metrics) { m.incr("trace.dropped"); }
            }
        "#;
        assert!(find_metric_literals("fixture.rs", gated).is_empty());
    }

    #[test]
    fn service_config_struct_literal_is_caught_everywhere() {
        let bad = r#"
            fn build() -> ServiceConfig {
                ServiceConfig { workers: 2, ..ServiceConfig::default() }
            }
            #[cfg(test)]
            mod tests {
                fn also_in_tests() {
                    let _ = ServiceConfig { workers: 1, ..Default::default() };
                }
            }
        "#;
        let hits = find_service_config_literals("fixture.rs", bad);
        assert_eq!(hits.len(), 2, "{hits:?}");
    }

    #[test]
    fn sanctioned_builder_calls_and_type_positions_are_allowed() {
        let ok = r#"
            fn build() -> ServiceConfig {
                ServiceConfig::sized(2, 4, 64).with_exec(ExecMode::Parked)
            }
            impl ServiceConfig {
                fn tweak(self) -> ServiceConfig {
                    self
                }
            }
            impl Default for ServiceConfig {
                fn default() -> ServiceConfig {
                    ServiceConfig::sized(1, 1, 1)
                }
            }
            struct ShardWorkerServiceConfig {
                x: u8,
            }
        "#;
        assert!(find_service_config_literals("fixture.rs", ok).is_empty());
    }

    #[test]
    fn duplicate_or_sparse_frame_tags_are_caught() {
        let dup = "const TAG_A: u8 = 1;\nconst TAG_B: u8 = 1;\n";
        assert!(check_frame_tags("f.rs", dup).iter().any(|v| v.contains("duplicate")));
        let sparse = "const TAG_A: u8 = 1;\nconst TAG_B: u8 = 3;\n";
        assert!(check_frame_tags("f.rs", sparse).iter().any(|v| v.contains("not dense")));
        let zero = "const TAG_A: u8 = 0;\nconst TAG_B: u8 = 1;\n";
        assert!(check_frame_tags("f.rs", zero).iter().any(|v| v.contains("reserved")));
        let ok = "const TAG_A: u8 = 1;\nconst TAG_B: u8 = 2;\nconst TAG_C: u8 = 3;\n";
        assert!(check_frame_tags("f.rs", ok).is_empty());
    }

    // Ident-style array, matching the real registry's shape.
    const NAMES_FIXTURE: &str = r#"
        pub const KERNEL_A: &str = "kernel.radix.minmax";
        pub const KERNEL_B: &str = "kernel.radix.scatter";
        pub const KERNEL_PHASES: [&str; 2] = [KERNEL_A, KERNEL_B];
    "#;

    const EVENT_FIXTURE: &str = r#"
        pub enum Phase {
            RadixMinMax = 0,
            RadixScatter = 1,
        }
        impl Phase {
            pub const COUNT: usize = 2;
            pub fn metric_name(self) -> &'static str {
                match self {
                    Phase::RadixMinMax => names::KERNEL_A,
                    Phase::RadixScatter => names::KERNEL_B,
                }
            }
        }
    "#;

    #[test]
    fn coherent_phase_tables_pass() {
        assert_eq!(check_phase_registry(NAMES_FIXTURE, EVENT_FIXTURE), Vec::<String>::new());
    }

    #[test]
    fn phase_table_drift_is_caught() {
        // A variant added to the enum without a KERNEL_PHASES entry.
        let grown = EVENT_FIXTURE
            .replace("RadixScatter = 1,", "RadixScatter = 1,\n            RadixCopyback = 2,")
            .replace("COUNT: usize = 2", "COUNT: usize = 3");
        assert!(check_phase_registry(NAMES_FIXTURE, &grown)
            .iter()
            .any(|v| v.contains("variants but KERNEL_PHASES")));
        // Sparse discriminants.
        let sparse = EVENT_FIXTURE.replace("RadixScatter = 1,", "RadixScatter = 5,");
        assert!(check_phase_registry(NAMES_FIXTURE, &sparse)
            .iter()
            .any(|v| v.contains("not dense")));
        // COUNT out of step.
        let stale = EVENT_FIXTURE.replace("COUNT: usize = 2", "COUNT: usize = 7");
        assert!(check_phase_registry(NAMES_FIXTURE, &stale)
            .iter()
            .any(|v| v.contains("Phase::COUNT")));
        // A span name outside the kernel.* namespace.
        let off = NAMES_FIXTURE.replace("\"kernel.radix.scatter\"", "\"radix.scatter\"");
        assert!(check_phase_registry(&off, EVENT_FIXTURE)
            .iter()
            .any(|v| v.contains("must start with")));
    }

    #[test]
    fn str_arrays_parse_both_literal_and_ident_elements() {
        assert_eq!(
            parse_str_array(NAMES_FIXTURE, "KERNEL_PHASES").unwrap(),
            vec!["kernel.radix.minmax", "kernel.radix.scatter"]
        );
        let literal = r#"pub const XS: [&str; 2] = ["a.b", "c.d"];"#;
        assert_eq!(parse_str_array(literal, "XS").unwrap(), vec!["a.b", "c.d"]);
        // An ident with no matching const resolves to a loud sentinel.
        let dangling = "pub const XS: [&str; 1] = [MISSING];";
        assert_eq!(parse_str_array(dangling, "XS").unwrap(), vec!["MISSING?"]);
    }

    const RUN_FIXTURE: &str = r#"
        let workers = doc.count("service", "workers", 2)?;
        let autotune = doc.bool("service", "autotune", false)?;
    "#;
    const README_FIXTURE: &str = "\
| Key | Default | Meaning |\n\
|---|---|---|\n\
| `workers` | 2 | concurrent jobs |\n\
| `autotune` | off | background GA |\n";
    const CLI_FIXTURE: &str = r#"
        let w = args.usize_or("workers", 2)?;
        if args.has("autotune") {}
    "#;

    #[test]
    fn knob_parity_passes_when_all_three_surfaces_agree() {
        assert_eq!(
            check_service_knob_parity(RUN_FIXTURE, README_FIXTURE, CLI_FIXTURE),
            Vec::<String>::new()
        );
    }

    #[test]
    fn knob_drift_is_caught_in_each_direction() {
        // Key missing from the README table.
        let run_extra = format!(
            "{RUN_FIXTURE}\nlet q = doc.count(\"service\", \"queue_capacity\", 64)?;"
        );
        let v = check_service_knob_parity(&run_extra, README_FIXTURE, CLI_FIXTURE);
        assert!(v.iter().any(|x| x.contains("queue_capacity") && x.contains("README")), "{v:?}");
        // …and the same key has no CLI flag.
        assert!(v.iter().any(|x| x.contains("--queue-capacity")), "{v:?}");
        // README documents a knob that does not exist.
        let readme_extra = format!("{README_FIXTURE}| `ghost_knob` | 1 | nothing |\n");
        assert!(check_service_knob_parity(RUN_FIXTURE, &readme_extra, CLI_FIXTURE)
            .iter()
            .any(|x| x.contains("ghost_knob")));
    }

    #[test]
    fn bench_schema_and_phase_keys_are_validated() {
        let phases = vec!["kernel.radix.scatter".to_string()];
        let ok = r#"{ "schema": "evosort-bench-v1", "entries": [] }"#;
        assert!(check_bench_report("B.json", ok, &phases).is_empty());
        let bad_schema = r#"{ "schema": "evosort-bench-v9" }"#;
        assert!(check_bench_report("B.json", bad_schema, &phases)
            .iter()
            .any(|v| v.contains("unknown schema")));
        let missing = r#"{ "entries": [] }"#;
        assert!(check_bench_report("B.json", missing, &phases)
            .iter()
            .any(|v| v.contains("no \"schema\"")));
        let stray_phase =
            r#"{ "schema": "evosort-bench-v2", "phases": { "kernel.bogus.step": 0.1 } }"#;
        assert!(check_bench_report("B.json", stray_phase, &phases)
            .iter()
            .any(|v| v.contains("kernel.bogus.step")));
        let good_phase =
            r#"{ "schema": "evosort-bench-v2", "phases": { "kernel.radix.scatter": 0.1 } }"#;
        assert!(check_bench_report("B.json", good_phase, &phases).is_empty());
    }

    #[test]
    fn readme_metric_names_must_sanitize_from_the_registry() {
        let names = r#"
            pub const JOBS_COMPLETED: &str = "jobs.completed";
            pub const ROUTER_QUEUE_DEPTH: &str = "router.queue_depth";
        "#;
        let registry = registry_prometheus_forms(names);
        assert!(registry.contains("evosort_jobs_completed"));
        let ok = "| `evosort_jobs_completed` | counter | jobs |\n\
                  | `evosort_kernel_<kernel>_<phase>` | summary | pattern row |\n";
        assert!(check_readme_metric_names(ok, &registry).is_empty());
        let bad = "| `evosort_jobs_compelted` | counter | typo |\n";
        assert!(check_readme_metric_names(bad, &registry)
            .iter()
            .any(|v| v.contains("evosort_jobs_compelted")));
    }

    #[test]
    fn prometheus_form_matches_the_metrics_module() {
        // Pinned to the same example as metrics::prometheus_name's test.
        assert_eq!(prometheus_form("jobs.completed"), "evosort_jobs_completed");
        assert_eq!(prometheus_form("kernel.radix.minmax"), "evosort_kernel_radix_minmax");
    }

    #[test]
    fn the_real_tree_is_clean() {
        let root = repo_root();
        assert_eq!(run_lint(&root), Vec::<String>::new());
    }
}
