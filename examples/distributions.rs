//! Adaptivity across input distributions: the motivation of the paper's
//! intro — fixed parameters that win on one workload lose on another; the
//! dispatcher + tuned thresholds must hold up everywhere.
//!
//! ```sh
//! cargo run --release --offline --example distributions
//! ```

use evosort::data::{generate_i64, validate, Distribution};
use evosort::prelude::*;
use evosort::symbolic::SymbolicModel;
use evosort::util::{default_threads, fmt_count, fmt_secs, timer};

fn main() {
    let n = 4_000_000;
    let threads = default_threads();
    let sorter = AdaptiveSorter::new(threads);
    let params = SymbolicModel::paper().params_for(n);
    let merge_params = SortParams { algorithm: ACode::Merge, ..params };

    println!(
        "{} elements per distribution, {threads} threads; radix {} vs merge {}\n",
        fmt_count(n),
        params,
        merge_params
    );
    println!("{:<14} {:>10} {:>10} {:>10}  winner", "distribution", "radix", "merge", "baseline");

    for &dist in Distribution::all() {
        if matches!(dist, Distribution::UniformRange(..)) {
            continue;
        }
        let data = generate_i64(n, dist, 21, threads);
        let fp = validate::fingerprint_i64(&data, threads);

        let mut a = data.clone();
        let (_, radix_secs) = timer::time(|| sorter.sort_i64(&mut a, &params));
        assert_eq!(validate::validate_i64(fp, &a, threads), validate::Verdict::Valid);

        let mut b = data.clone();
        let (_, merge_secs) = timer::time(|| sorter.sort_i64(&mut b, &merge_params));
        assert_eq!(b, a);

        let mut c = data.clone();
        let (_, base_secs) = timer::time(|| Baseline::Quicksort.sort_i64(&mut c));
        assert_eq!(c, a);

        let winner = if radix_secs < merge_secs { "radix" } else { "merge" };
        println!(
            "{:<14} {:>10} {:>10} {:>10}  {winner}",
            dist.name(),
            fmt_secs(radix_secs),
            fmt_secs(merge_secs),
            fmt_secs(base_secs)
        );
    }
    println!("\n(nearly-sorted/sorted favour merge's galloping; uniform favours radix)");
}
