//! END-TO-END DRIVER — the full EvoSort system on a real workload, proving
//! all layers compose (recorded in EXPERIMENTS.md §E2E):
//!
//!   L1 Pallas bitonic kernel  → AOT HLO artifact (`make artifacts`)
//!   L2 JAX tile-sort graph    → loaded by the PJRT runtime
//!   L3 rust coordinator       → GA tuning + Adaptive Partition Sort +
//!                               master pipeline + validation + baselines
//!
//! Runs Algorithm 1 (GA-tuned) over three sizes, then exercises the XLA
//! tile-sort backend (`A_code = 5`) on i32 data, then the symbolic path.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example e2e_pipeline
//! ```

use evosort::coordinator::{pipeline, ParamSource, PipelineConfig};
use evosort::data::{generate_i32, Distribution};
use evosort::ga::GaConfig;
use evosort::prelude::*;
use evosort::runtime::XlaTileSorter;
use evosort::util::{default_threads, fmt_count, fmt_secs, timer};

fn main() {
    let threads = default_threads();

    // --- Stage 1: the master pipeline (Algorithm 1), GA-tuned. ------------
    println!("=== Stage 1: master pipeline (GA-tuned, Algorithm 1) ===");
    let config = PipelineConfig {
        sizes: vec![500_000, 2_000_000, 8_000_000],
        dist: Distribution::Uniform,
        seed: 42,
        threads,
        params: ParamSource::Ga(GaConfig {
            population: 10,
            generations: 5,
            seed: 42,
            ..GaConfig::default()
        }),
        sample_cap: 1_000_000,
        baselines: vec![Baseline::Quicksort, Baseline::Mergesort],
    };
    let rows = pipeline::run(&config);
    println!("\n n       EvoSort    best-baseline  speedup  valid");
    for r in &rows {
        let best_base =
            r.baselines.iter().map(|(_, t, _)| *t).fold(f64::INFINITY, f64::min);
        println!(
            " {:<7} {:<10} {:<13} {:<7.2}x {}",
            fmt_count(r.n),
            fmt_secs(r.evosort_secs),
            fmt_secs(best_base),
            r.best_speedup(),
            r.validated
        );
        assert!(r.validated, "pipeline row must validate");
    }

    // --- Stage 2: the XLA tile backend (L1+L2+runtime on the hot path). ---
    println!("\n=== Stage 2: XLA tile-sort backend (A_code = 5) ===");
    match XlaTileSorter::from_default_artifacts() {
        Ok(backend) => {
            let sorter = AdaptiveSorter::new(threads).with_xla(std::sync::Arc::new(backend));
            let params = SortParams {
                algorithm: ACode::XlaTile,
                fallback_threshold: 1024,
                ..SortParams::default()
            };
            let n = 300_000;
            let mut data = generate_i32(n, Distribution::Uniform, 7, threads);
            let mut expect = data.clone();
            expect.sort_unstable();
            let (_, secs) = timer::time(|| sorter.sort_i32(&mut data, &params));
            assert_eq!(data, expect, "XLA-backed sort must be correct");
            println!(
                "sorted {} i32 via Pallas-bitonic tiles + rust merge in {} — exact match vs oracle",
                fmt_count(n),
                fmt_secs(secs)
            );
        }
        Err(e) => {
            println!("SKIPPED: artifacts unavailable ({e}); run `make artifacts`");
        }
    }

    // --- Stage 3: symbolic deployment (§7, Table 2 scenario). -------------
    println!("\n=== Stage 3: symbolic-parameter pipeline (zero tuning) ===");
    let config = PipelineConfig {
        sizes: vec![4_000_000],
        params: ParamSource::Symbolic(evosort::symbolic::SymbolicModel::paper()),
        threads,
        baselines: vec![Baseline::Quicksort],
        ..PipelineConfig::default()
    };
    let rows = pipeline::run(&config);
    for r in &rows {
        assert!(r.validated);
        println!(
            " {}: {} vs baseline {} -> {:.2}x (params {})",
            fmt_count(r.n),
            fmt_secs(r.evosort_secs),
            fmt_secs(r.baselines[0].1),
            r.best_speedup(),
            r.params
        );
    }
    println!("\nE2E OK: all stages validated.");
}
