//! GA tuning demo (§6 of the paper): run `RunGATuning` for one dataset size
//! and print the per-generation convergence series behind Figures 2–6.
//!
//! ```sh
//! cargo run --release --offline --example ga_tuning
//! ```

use evosort::data::Distribution;
use evosort::ga::{GaConfig, GaDriver};
use evosort::prelude::*;
use evosort::util::{default_threads, fmt_count, fmt_secs};

fn main() {
    let n = 2_000_000;
    let threads = default_threads();
    let cfg = GaConfig {
        population: 12,
        generations: 8,
        crossover_prob: 0.7, // paper §6
        mutation_prob: 0.3,  // paper §6
        seed: 7,
        ..GaConfig::default()
    };
    println!(
        "GA tuning for n={} ({} individuals x {} generations, crossover 0.7, mutation 0.3)",
        fmt_count(n),
        cfg.population,
        cfg.generations
    );

    let driver = GaDriver::new(cfg);
    let result = driver.run_for_size(n, n, Distribution::Uniform, AdaptiveSorter::new(threads));

    println!("\n gen |   best    |   avg     |  worst    | best genome");
    println!("-----+-----------+-----------+-----------+------------");
    for h in &result.history {
        println!(
            " {:>3} | {:>9} | {:>9} | {:>9} | {:?}",
            h.generation,
            fmt_secs(h.best),
            fmt_secs(h.average),
            fmt_secs(h.worst),
            h.best_genome
        );
    }
    println!(
        "\nbest individual: {}  fitness {}  ({} timed evaluations)",
        result.best,
        fmt_secs(result.best_fitness),
        result.evaluations
    );
    // The hallmark of Figures 2–6: generation-0 spread collapses rapidly.
    let g0 = &result.history[0];
    let last = result.history.last().unwrap();
    println!(
        "gen-0 spread {:.4}s -> final avg {:.4}s ({}x tighter)",
        g0.worst - g0.best,
        last.average - last.best,
        ((g0.worst - g0.best) / (last.average - last.best).max(1e-9)) as u64
    );
}
