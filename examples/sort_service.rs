//! The coordinator as a long-running service: the typed async job API —
//! mixed-dtype requests (i64 + f64), non-blocking tickets, parameter
//! resolution (override → dtype-tagged fingerprint cache → symbolic model),
//! result streaming over a batch, and a metrics report.
//!
//! ```sh
//! cargo run --release --offline --example sort_service
//! ```

use evosort::coordinator::metrics::names;
use evosort::coordinator::{ServiceConfig, SortRequest, SortService};
use evosort::data::{generate_i64, Distribution};
use evosort::prelude::*;
use evosort::util::{default_threads, fmt_count, fmt_secs};

fn main() {
    let threads = default_threads();
    let svc = SortService::new(ServiceConfig::sized(2, threads.div_ceil(2), 8));

    // Pre-warm the tuning cache for one workload class, as a tuned
    // deployment would (other classes fall back to the symbolic model).
    // The cache keys on a dtype-tagged fingerprint of the data itself, so
    // derive the label from a representative array, not a distribution name.
    let representative = generate_i64(1_000_000, Distribution::Uniform, 0, threads);
    let label = SortService::fingerprint_label(&representative);
    svc.cache().put(representative.len(), &label, SortParams::paper_1e7());

    let workloads = [
        ("uniform", Distribution::Uniform, 1_000_000usize),
        ("zipf", Distribution::Zipf, 800_000),
        ("gaussian", Distribution::Gaussian, 1_200_000),
        ("nearly-sorted", Distribution::NearlySorted, 1_000_000),
    ];

    // Mixed-dtype traffic through one service: even jobs as i64, odd as f64
    // (floats sort in IEEE-754 total_cmp order — NaNs are keys, not errors).
    println!("submitting 12 jobs across {} workload classes...", workloads.len());
    let tickets: Vec<Ticket> = (0..12)
        .map(|i| {
            let (name, dist, n) = workloads[i % workloads.len()];
            let ints = generate_i64(n, dist, i as u64, threads);
            let req = if i % 2 == 0 {
                SortRequest::new(ints)
            } else {
                let floats: Vec<f64> = ints.into_iter().map(|x| x as f64).collect();
                SortRequest::new(floats)
            };
            svc.submit_request(req.with_dist(name))
        })
        .collect();

    for t in tickets {
        let out = t.wait().expect("job completed");
        assert!(out.valid, "job {} invalid", out.id);
        println!(
            "job {:>2}: {:>6} {} elems in {:>9}  params={}",
            out.id,
            fmt_count(out.len()),
            out.dtype(),
            fmt_secs(out.secs),
            out.params
        );
    }

    // Result streaming: consume a batch in submission order as jobs finish,
    // no whole-batch barrier.
    let batch: Vec<SortRequest> = (0..8)
        .map(|i| {
            let data = generate_i64(200_000, Distribution::Uniform, 100 + i, threads);
            SortRequest::new(data)
        })
        .collect();
    let mut streamed = 0usize;
    for result in svc.submit_batch_requests(batch).stream() {
        let out = result.expect("batch job completed");
        assert!(out.valid);
        streamed += 1;
        println!("streamed result {streamed}/8 (job {} done)", out.id);
    }

    svc.drain();
    println!("\nmetrics:\n{}", svc.metrics().report());
    let hits = svc.metrics().counter(names::PARAMS_CACHE_HIT);
    let sym = svc.metrics().counter(names::PARAMS_SYMBOLIC);
    println!("cache hits: {hits}, symbolic fallbacks: {sym}");
    assert_eq!(svc.metrics().counter(names::JOBS_COMPLETED), 20);
    assert_eq!(svc.metrics().counter(names::JOBS_INVALID), 0);
    assert_eq!(svc.metrics().counter(names::JOBS_DTYPE_F64), 6);
}
