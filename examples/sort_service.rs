//! The coordinator as a long-running service: concurrent job submission with
//! backpressure, parameter resolution (override → tuning cache → symbolic
//! model), validation, and a metrics report.
//!
//! ```sh
//! cargo run --release --offline --example sort_service
//! ```

use evosort::coordinator::{ServiceConfig, SortJob, SortService};
use evosort::data::{generate_i64, Distribution};
use evosort::prelude::*;
use evosort::util::{default_threads, fmt_count, fmt_secs};

fn main() {
    let threads = default_threads();
    let svc = SortService::new(ServiceConfig {
        workers: 2,
        sort_threads: threads.div_ceil(2),
        queue_capacity: 8, // small queue => visible backpressure
        autotune: None,    // see `serve --autotune` for the online tuner
    });

    // Pre-warm the tuning cache for one workload class, as a tuned
    // deployment would (other classes fall back to the symbolic model).
    // The cache keys on a fingerprint of the data itself, so derive the
    // label from a representative array rather than a distribution name.
    let representative = generate_i64(1_000_000, Distribution::Uniform, 0, threads);
    let label = SortService::fingerprint_label(&representative);
    svc.cache().put(representative.len(), &label, SortParams::paper_1e7());

    let workloads = [
        ("uniform", Distribution::Uniform, 1_000_000usize),
        ("zipf", Distribution::Zipf, 800_000),
        ("gaussian", Distribution::Gaussian, 1_200_000),
        ("nearly-sorted", Distribution::NearlySorted, 1_000_000),
    ];

    println!("submitting 12 jobs across {} workload classes...", workloads.len());
    let handles: Vec<_> = (0..12)
        .map(|i| {
            let (name, dist, n) = workloads[i % workloads.len()];
            let data = generate_i64(n, dist, i as u64, threads);
            let mut job = SortJob::new(data);
            job.dist = name.to_string();
            svc.submit(job)
        })
        .collect();

    for h in handles {
        let out = h.wait();
        assert!(out.valid, "job {} invalid", out.id);
        println!(
            "job {:>2}: {:>6} elems in {:>9}  params={}",
            out.id,
            fmt_count(out.data.len()),
            fmt_secs(out.secs),
            out.params
        );
    }

    svc.drain();
    println!("\nmetrics:\n{}", svc.metrics().report());
    let hits = svc.metrics().counter("params.cache_hit");
    let sym = svc.metrics().counter("params.symbolic");
    println!("cache hits: {hits}, symbolic fallbacks: {sym}");
    assert_eq!(svc.metrics().counter("jobs.completed"), 12);
    assert_eq!(svc.metrics().counter("jobs.invalid"), 0);
}
