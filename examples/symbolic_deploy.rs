//! Symbolic-model deployment (§7): fit quadratics to a GA sweep on *this*
//! machine, compare the fitted curves with the paper's Eqs. (1)-(4), then
//! sort with zero tuning overhead (the Table 2 scenario).
//!
//! ```sh
//! cargo run --release --offline --example symbolic_deploy
//! ```

use evosort::data::{generate_i64, Distribution};
use evosort::ga::{GaConfig, GaDriver};
use evosort::prelude::*;
use evosort::symbolic::SymbolicModel;
use evosort::util::{default_threads, fmt_count, fmt_secs, timer};

fn main() {
    let threads = default_threads();
    let sweep_sizes = [100_000usize, 300_000, 1_000_000, 3_000_000, 10_000_000];

    // 1. GA sweep (the training data of Figures 7-11).
    println!("GA sweep over {} sizes:", sweep_sizes.len());
    let mut points = Vec::new();
    for &n in &sweep_sizes {
        let cfg = GaConfig { population: 8, generations: 4, seed: 11 ^ n as u64, ..Default::default() };
        let r = GaDriver::new(cfg).run_for_size(n, 2_000_000, Distribution::Uniform, AdaptiveSorter::new(threads));
        println!("  n={:<6} best={} {}", fmt_count(n), fmt_secs(r.best_fitness), r.best);
        points.push((n, r.best));
    }

    // 2. Fit degree-2 models in x = log10 n (the paper's §7.1 form).
    let fitted = SymbolicModel::fit(&points).expect("fit");
    let paper = SymbolicModel::paper();
    println!("\nfitted vs paper quadratics (vertex x* = -b/2a):");
    for (name, f, p) in [
        ("T_insertion", fitted.insertion, paper.insertion),
        ("T_par_merge", fitted.parallel_merge, paper.parallel_merge),
        ("T_fallback ", fitted.fallback, paper.fallback),
        ("T_tile     ", fitted.tile, paper.tile),
    ] {
        println!(
            "  {name}: fitted a={:+.1} x*={:.2} | paper a={:+.1} x*={:.2}",
            f.a,
            f.vertex_x(),
            p.a,
            p.vertex_x()
        );
    }

    // 3. Deploy: closed-form parameters, zero tuning overhead (Table 2).
    let n = 20_000_000;
    let params = fitted.params_for(n);
    println!("\ndeploy at n={}: params {params}", fmt_count(n));
    let mut data = generate_i64(n, Distribution::Uniform, 99, threads);
    let sorter = AdaptiveSorter::new(threads);
    let (_, secs) = timer::time(|| sorter.sort_i64(&mut data, &params));
    assert!(data.windows(2).all(|w| w[0] <= w[1]));
    println!("sorted {} in {} — no GA run needed", fmt_count(n), fmt_secs(secs));
}
