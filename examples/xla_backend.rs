//! The three-layer seam in isolation: load the AOT artifacts, run the Pallas
//! bitonic tile sorter and the radix-histogram kernel through PJRT from
//! rust, and cross-check both against rust oracles.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example xla_backend
//! ```

use evosort::data::{generate_i32, Distribution};
use evosort::runtime::{Manifest, XlaTileSorter};
use evosort::sort::TileSorter;
use evosort::util::{fmt_secs, timer};

fn main() -> anyhow::Result<()> {
    let dir = Manifest::default_dir();
    let manifest = Manifest::load(&dir)?;
    println!("artifacts in {}:", manifest.dir.display());
    for e in &manifest.entries {
        println!("  {} (batch={} tile={})", e.kind, e.batch, e.tile);
    }

    let backend = XlaTileSorter::new(&manifest)?;
    let tile = backend.tile_size();
    let batch = backend.batch();

    // --- Tile sort through the Pallas bitonic artifact. -------------------
    let n_tiles = batch * 2 + 3; // forces a padded partial batch
    let mut data = generate_i32(tile * n_tiles, Distribution::Uniform, 3, 2);
    let reference: Vec<i32> = data
        .chunks(tile)
        .flat_map(|c| {
            let mut v = c.to_vec();
            v.sort_unstable();
            v
        })
        .collect();
    let (_, secs) = timer::time(|| backend.sort_tiles_i32(&mut data).unwrap());
    assert_eq!(data, reference, "tile sort must match the rust oracle");
    println!(
        "\ntile_sort: {} tiles x {} sorted via PJRT in {} — matches oracle",
        n_tiles,
        tile,
        fmt_secs(secs)
    );

    // --- Histograms through the Pallas one-hot-reduction artifact. --------
    let hdata = generate_i32(tile * batch, Distribution::Uniform, 5, 2);
    for shift in [0, 8, 16, 24] {
        let (hists, secs) =
            timer::time(|| backend.histogram_batch(hdata.clone(), shift).unwrap());
        // Rust oracle.
        for (b, block) in hdata.chunks(tile).enumerate() {
            let mut want = [0i32; 256];
            for &x in block {
                want[((x as u32 >> shift) & 0xFF) as usize] += 1;
            }
            assert_eq!(&hists[b * 256..(b + 1) * 256], &want[..]);
        }
        println!("radix_hist shift={shift:>2}: {} blocks verified in {}", batch, fmt_secs(secs));
    }

    println!("\nxla_backend OK — L1 (Pallas) + L2 (JAX) + runtime (PJRT) compose.");
    Ok(())
}
