//! Quickstart: sort 10M integers with EvoSort and compare against the
//! sequential library baseline.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```

use evosort::data::{generate_i64, validate, Distribution};
use evosort::prelude::*;
use evosort::symbolic::SymbolicModel;
use evosort::util::{default_threads, fmt_count, fmt_secs, timer};

fn main() {
    let n = 10_000_000;
    let threads = default_threads();
    println!("EvoSort quickstart: {} uniform i64, {threads} threads", fmt_count(n));

    // 1. Generate the paper's workload: uniform integers in [-1e9, 1e9].
    let data = generate_i64(n, Distribution::Uniform, 42, threads);
    let fp = validate::fingerprint_i64(&data, threads);

    // 2. Parameters from the symbolic model (§7) — no tuning run needed.
    let params = SymbolicModel::paper().params_for(n);
    println!("symbolic params: {params}");

    // 3. Sort.
    let sorter = AdaptiveSorter::new(threads);
    let mut evo = data.clone();
    let (_, evo_secs) = timer::time(|| sorter.sort_i64(&mut evo, &params));

    // 4. Validate (ordering + multiset preservation).
    assert_eq!(validate::validate_i64(fp, &evo, threads), validate::Verdict::Valid);
    println!("evosort:  {} ({:.1} Melem/s)", fmt_secs(evo_secs), n as f64 / evo_secs / 1e6);

    // 5. Baseline comparison (the np.sort analog).
    let mut base = data.clone();
    let (_, base_secs) = timer::time(|| Baseline::Quicksort.sort_i64(&mut base));
    assert_eq!(base, evo);
    println!("baseline: {} ({:.1} Melem/s)", fmt_secs(base_secs), n as f64 / base_secs / 1e6);
    println!("speedup:  {:.2}x", base_secs / evo_secs);
}
